"""Sharded inference over the Lattica mesh (paper Fig. 1, Scenario 4).

A model is split into pipeline shards; each shard runs on a peer (possibly
behind a NAT) and serves the ``infer.<fleet>`` RPC.  Shard servers announce
themselves as DHT providers of ``shard/<fleet>/<i>``; the shard-aware client
stub resolves providers per hop, streams activations through the pipeline,
and **transparently fails over** to replica shards via a fresh DHT lookup
when a provider dies — the availability story of the paper's §2 RPC layer.

Two RPC surfaces per shard:

* ``infer.<fleet>.<i>`` — the v1 single-session ops (prefill/decode/score),
  kept for back-compat.
* ``infer.v2.*.<fleet>.<i>`` — the continuous-batching plane: ``open``
  admits a session into the shard's :class:`~repro.serving.batch.BatchEngine`
  slot table (FIFO-queueing when full), ``step`` advances *many* sessions in
  one wire message, ``close`` evicts.  One RPC per shard hop per decode
  iteration is shared by every active session, which is where batching beats
  the sequential path: per-message CPU and link latency amortize across the
  batch while per-token FLOPs stay identical.

:class:`ShardClient` routes via a load-aware :class:`LoadAwareRouter`
(EWMA latency / error rate / in-flight depth per provider) instead of
first-successful-dial, hedges idempotent calls, and **migrates** sessions
mid-generation: when a provider dies between decode steps the driver
replays prompt ⊕ generated-so-far through a freshly routed chain, so a
crash loses no session (``sessions_migrated`` in the dashboard).

This module is the mesh-level (cross-NAT) serving path at example scale;
datacenter-scale tensor-parallel serving is ``repro.launch.serve``.
"""

from __future__ import annotations

import hashlib
import itertools
from collections import deque
from typing import Any, Deque, Dict, Generator, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dht import PeerInfo
from repro.core.node import LatticaNode
from repro.core.rpc import RpcContext, RpcError
from repro.core.service import (Fixed, RpcStatus, Service, ServiceError,
                                TensorDictCodec, pickled, unary)
from repro.core.simnet import DialError
from repro.models import decoder
from repro.models.common import rms_norm
from repro.models.config import ModelConfig

from .batch import PEER_FLOPS, BatchEngine
from .router import LoadAwareRouter, hedged_call

_session_seq = itertools.count(1)


def shard_key(fleet: str, idx: int) -> bytes:
    return hashlib.sha256(f"shard/{fleet}/{idx}".encode()).digest()


def plan_shards(cfg: ModelConfig, n_shards: int) -> List[Tuple[int, int]]:
    """Split layers into contiguous ranges, as even as possible."""
    L = cfg.n_layers
    base, rem = divmod(L, n_shards)
    plan = []
    lo = 0
    for i in range(n_shards):
        hi = lo + base + (1 if i < rem else 0)
        plan.append((lo, hi))
        lo = hi
    return plan


def split_params(cfg: ModelConfig, params: Any,
                 plan: List[Tuple[int, int]]) -> List[Dict[str, Any]]:
    """Per-shard param subsets (first gets embed, last gets norm+head)."""
    shards = []
    for i, (lo, hi) in enumerate(plan):
        sub: Dict[str, Any] = {}
        if cfg.arch == "ssm":
            sub["blocks"] = params["blocks"][lo:hi]
        else:
            sub["blocks"] = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
        if i == 0:
            sub["embed"] = params["embed"]
        if i == len(plan) - 1:
            sub["final_norm"] = params["final_norm"]
            if "lm_head" in params:
                sub["lm_head"] = params["lm_head"]
            elif cfg.tie_embeddings:
                sub["embed_out"] = params["embed"]
        shards.append(sub)
    return shards


class ShardModule:
    """Applies one shard's layer range, with per-session decode caches."""

    def __init__(self, cfg: ModelConfig, params: Dict[str, Any],
                 layer_range: Tuple[int, int], is_first: bool, is_last: bool):
        self.cfg = cfg
        self.params = params
        self.lo, self.hi = layer_range
        self.is_first = is_first
        self.is_last = is_last

    @property
    def n_layers(self) -> int:
        return self.hi - self.lo

    def _layer_params(self, j: int) -> Any:
        if self.cfg.arch == "ssm":
            return self.params["blocks"][j]
        return jax.tree.map(lambda a: a[j], self.params["blocks"])

    def embed(self, tokens: jax.Array) -> jax.Array:
        return jnp.take(self.params["embed"], tokens, axis=0)

    def head(self, x: jax.Array) -> jax.Array:
        x = rms_norm(x, self.params["final_norm"], self.cfg.norm_eps)
        w = self.params.get("lm_head")
        if w is None:
            w = self.params["embed_out"].T
        return x @ w

    def init_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        full = decoder.init_cache(self.cfg, batch, max_len)
        if self.cfg.arch == "ssm":
            layers = full["layers"][self.lo:self.hi]
        else:
            layers = jax.tree.map(lambda a: a[self.lo:self.hi], full["layers"])
        return {"len": full["len"], "layers": layers}

    def apply(self, x: jax.Array, positions: jax.Array,
              cache: Optional[Dict[str, Any]]) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
        cache_len = cache["len"] if cache is not None else None
        new_layers: List[Any] = []
        for j in range(self.n_layers):
            lp = self._layer_params(j)
            if cache is not None:
                if self.cfg.arch == "ssm":
                    lc = cache["layers"][j]
                else:
                    lc = jax.tree.map(lambda a: a[j], cache["layers"])
            else:
                lc = None
            x, nc, _ = decoder.run_block(
                self.cfg, lp, x, positions, lc, cache_len,
                layer_idx=self.lo + j)
            new_layers.append(nc)
        new_cache = None
        if cache is not None:
            if self.cfg.arch == "ssm":
                stacked = new_layers
            else:
                stacked = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *new_layers)
            new_cache = {"len": cache_len + x.shape[1], "layers": stacked}
        return x, new_cache

    def flops(self, tokens: int) -> float:
        per_layer = 12 * self.cfg.d_model ** 2
        return 2.0 * tokens * per_layer * self.n_layers

    def weight_bytes(self) -> int:
        """Bytes the accelerator streams to apply this shard once — what
        the bandwidth term of the decode cost model charges per pass."""
        return sum(np.prod(leaf.shape) * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.params))


class InferenceService(Service):
    """One pipeline shard's v1 RPC surface.  ``scope`` carries the fleet
    name and shard index, so each shard serves ``infer.<fleet>.<i>``.  The
    infer method is *not* idempotent (decode advances per-session KV
    caches); failover is handled explicitly by :class:`ShardClient`."""

    name = "infer"

    def __init__(self, server: "ShardServer"):
        self.server = server
        self.scope = f"{server.fleet}.{server.shard_idx}"

    @unary("infer", request=TensorDictCodec(), response=TensorDictCodec(),
           timeout=120.0)
    def infer(self, payload: Any, ctx: RpcContext) -> Generator:
        if not self.server.alive:
            raise ServiceError(RpcStatus.UNAVAILABLE,
                               f"shard {self.server.shard_idx} is down")
        resp = yield from self.server._handle(payload, ctx)
        return resp

    @unary("score", request=TensorDictCodec(), response=TensorDictCodec(),
           timeout=120.0, idempotent=True)
    def score(self, payload: Any, ctx: RpcContext) -> Generator:
        """Stateless forward pass: touches no session state, so it is the
        one v1 op that may be hedged/retried (latlint L004 requires the
        idempotency to be declared on the MethodSpec, not assumed)."""
        if payload.get("op") != "score":
            raise ServiceError(RpcStatus.NOT_FOUND,
                               "score method only serves op == 'score'")
        if not self.server.alive:
            raise ServiceError(RpcStatus.UNAVAILABLE,
                               f"shard {self.server.shard_idx} is down")
        resp = yield from self.server._handle(payload, ctx)
        return resp


class InferenceV2Service(Service):
    """The continuous-batching surface: per-step admission/eviction against
    the shard's slot table.  ``open``/``step`` are *not* idempotent (they
    advance KV caches); ``close``/``stats`` are."""

    name = "infer.v2"

    def __init__(self, server: "ShardServer"):
        self.server = server
        self.scope = f"{server.fleet}.{server.shard_idx}"

    def _check_alive(self) -> None:
        if not self.server.alive:
            raise ServiceError(RpcStatus.UNAVAILABLE,
                               f"shard {self.server.shard_idx} is down")

    @unary("infer.v2.open", request=TensorDictCodec(),
           response=TensorDictCodec(), timeout=120.0)
    def open(self, payload: Any, ctx: RpcContext) -> Generator:
        self._check_alive()
        eng = self.server.engine
        out, cost = yield from eng.open(
            tuple(payload["session"]), payload["x"], payload["max_len"])
        self._check_alive()     # died while we waited for a slot / computed
        yield ctx.cpu(cost)
        return {"x": out}

    @unary("infer.v2.step", request=TensorDictCodec(),
           response=TensorDictCodec(), timeout=60.0)
    def step(self, payload: Any, ctx: RpcContext) -> Generator:
        self._check_alive()
        eng = self.server.engine
        sessions = [tuple(s) for s in payload["sessions"]]
        evict = [tuple(s) for s in payload.get("evict", [])]
        out, served, cost = eng.step(sessions, payload["x"], evict=evict)
        yield ctx.cpu(cost)
        return {"x": out, "served": served}

    @unary("infer.v2.close", request=pickled(floor=96),
           response=pickled(floor=96), idempotent=True, timeout=15.0)
    def close(self, sessions: Any, ctx: RpcContext) -> Generator:
        yield ctx.cpu(2e-6)
        return self.server.engine.close([tuple(s) for s in sessions])

    @unary("infer.v2.stats", request=Fixed(64), response=pickled(floor=96),
           idempotent=True, timeout=10.0)
    def stats(self, payload: Any, ctx: RpcContext) -> Generator:
        self._check_alive()
        yield ctx.cpu(1e-6)
        eng = self.server.engine
        return {"slots_used": eng.slots_used, "n_slots": eng.n_slots,
                "queue_depth": eng.queue_depth}


class ShardServer:
    def __init__(self, node: LatticaNode, cfg: ModelConfig, fleet: str,
                 shard_idx: int, module: ShardModule, n_slots: int = 8,
                 page_size: int = 32, idle_ttl: float = 60.0,
                 kv_dtype: str = "fp32"):
        self.node = node
        self.cfg = cfg
        self.fleet = fleet
        self.shard_idx = shard_idx
        self.module = module
        self.sessions: Dict[Any, Dict[str, Any]] = {}    # v1 sessions
        self.alive = True
        self.idle_ttl = idle_ttl
        self.stats = {"prefill": 0, "decode": 0, "score": 0}
        self.engine = BatchEngine(module, node.sim, n_slots=n_slots,
                                  page_size=page_size, kv_dtype=kv_dtype)
        node.serve(InferenceService(self))
        node.serve(InferenceV2Service(self))
        if not hasattr(node, "shard_servers"):
            node.shard_servers = []                      # metrics registry
        node.shard_servers.append(self)
        node.sim.process(self._reaper(), daemon=True)

    def announce(self) -> Generator:
        yield from self.node.dht.provide(shard_key(self.fleet, self.shard_idx))
        return None

    def unannounce(self) -> Generator:
        """Withdraw this replica's DHT provider record (planned retirement
        — the inverse of :meth:`announce`; routers stop finding it)."""
        yield from self.node.dht.unprovide(
            shard_key(self.fleet, self.shard_idx))
        return None

    def stop(self) -> None:
        """Simulate a crash: all subsequent calls fail, and admissions
        parked on the slot queue fail *now* rather than at RPC deadline."""
        self.alive = False
        self.engine.fail_waiters(ServiceError(
            RpcStatus.UNAVAILABLE, f"shard {self.shard_idx} is down"))

    def _reaper(self) -> Generator:
        """Evict slots pinned by vanished clients (crash between steps,
        client-side deadline abandoning a queued admission)."""
        while self.alive:
            yield max(1.0, self.idle_ttl / 2)
            self.engine.reap_idle(self.idle_ttl)
        return None

    def _handle(self, payload: Any, ctx: RpcContext) -> Generator:
        op = payload["op"]
        m = self.module
        if op == "prefill":
            self.stats["prefill"] += 1
            x = jnp.asarray(payload["x"])
            if m.is_first and x.dtype == jnp.int32:
                x = m.embed(x)
            B, S = x.shape[0], x.shape[1]
            cache = m.init_cache(B, payload["max_len"])
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            if self.cfg.mrope:
                positions = jnp.broadcast_to(positions[None], (3, B, S))
            out, cache = m.apply(x, positions, cache)
            self.sessions[payload["session"]] = cache
            if m.is_last:
                out = m.head(out[:, -1:])[:, 0]
            else:
                out = out
            yield ctx.cpu(m.flops(B * S) / PEER_FLOPS)
            return {"x": np.asarray(out)}
        if op == "decode":
            self.stats["decode"] += 1
            cache = self.sessions.get(payload["session"])
            if cache is None:
                # a replica that never saw this session's prefill: typed
                # NOT_FOUND so the client migrates instead of treating the
                # replica as dead
                raise ServiceError(
                    RpcStatus.NOT_FOUND,
                    f"unknown session {payload['session']!r}")
            x = jnp.asarray(payload["x"])
            if m.is_first and x.dtype == jnp.int32:
                x = m.embed(x[:, None])
            B = x.shape[0]
            pos = jnp.broadcast_to(
                cache["len"][None, None], (B, 1)).astype(jnp.int32)
            if self.cfg.mrope:
                pos = jnp.broadcast_to(pos[None], (3, B, 1))
            out, cache = m.apply(x, pos, cache)
            self.sessions[payload["session"]] = cache
            if m.is_last:
                out = m.head(out)[:, 0]
            yield ctx.cpu(m.flops(B) / PEER_FLOPS)
            return {"x": np.asarray(out)}
        if op == "score":
            self.stats["score"] += 1
            x = jnp.asarray(payload["x"])
            if m.is_first and x.dtype == jnp.int32:
                x = m.embed(x)
            B, S = x.shape[0], x.shape[1]
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            if self.cfg.mrope:
                positions = jnp.broadcast_to(positions[None], (3, B, S))
            out, _ = m.apply(x, positions, None)
            if m.is_last:
                out = m.head(out)
            yield ctx.cpu(m.flops(B * S) / PEER_FLOPS)
            return {"x": np.asarray(out)}
        raise ServiceError(RpcStatus.NOT_FOUND, f"unknown op {op}")


class _Request:
    """One in-flight generation request inside the v2 driver."""

    __slots__ = ("prompt", "n_tokens", "temperature", "rng", "generated",
                 "session", "chain", "done", "attempts", "migrations",
                 "submitted_at", "finished_at")

    def __init__(self, prompt: np.ndarray, n_tokens: int, temperature: float,
                 seed: int, done: Any, now: float):
        self.prompt = prompt                 # (1, S) int32
        self.n_tokens = n_tokens
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        self.generated: List[int] = []
        self.session: Optional[Tuple[str, int]] = None
        self.chain: List[PeerInfo] = []
        self.done = done
        self.attempts = 0
        self.migrations = 0
        self.submitted_at = now
        self.finished_at: Optional[float] = None


class ShardClient:
    """Shard-aware stub: DHT provider resolution, load-aware routing,
    transparent failover, and a continuous-batching driver.

    The v1 methods (``prefill``/``decode_step``/``score``/``generate``)
    keep their one-session-at-a-time semantics.  The v2 driver
    (``submit``/``generate_concurrent``) multiplexes any number of
    concurrent sessions over one ``infer.v2.step`` RPC per shard hop per
    decode iteration, sampling client-side, and migrates sessions off dead
    providers by replaying prompt ⊕ generated-so-far on a fresh chain.
    """

    def __init__(self, node: LatticaNode, cfg: ModelConfig, fleet: str,
                 n_shards: int, resolve_ttl: float = 5.0,
                 hedge_after: float = 0.08, max_session_attempts: int = 8,
                 max_migrations: int = 10):
        self.node = node
        self.cfg = cfg
        self.fleet = fleet
        self.n_shards = n_shards
        self.resolve_ttl = resolve_ttl
        self.hedge_after = hedge_after
        self.max_session_attempts = max_session_attempts
        self.max_migrations = max_migrations
        self.router = LoadAwareRouter(node.sim)
        self._providers: Dict[int, List[PeerInfo]] = {}
        self._resolved_at: Dict[int, float] = {}
        self.stats = {"failovers": 0, "calls": 0, "sessions_migrated": 0,
                      "hedged": 0, "requests": 0, "completed": 0,
                      "failed_sessions": 0}
        self._pending: Deque[_Request] = deque()
        self._admitting: Set[_Request] = set()
        self._active: List[_Request] = []
        self._pump_alive = False
        self._wake: Optional[Any] = None
        if not hasattr(node, "shard_clients"):
            node.shard_clients = []                      # metrics registry
        node.shard_clients.append(self)

    # -- provider resolution -------------------------------------------------
    def _resolve(self, idx: int, refresh: bool = False) -> Generator:
        stale = (self.node.sim.now - self._resolved_at.get(idx, -1e9)
                 > self.resolve_ttl)
        if (refresh or stale or idx not in self._providers
                or not self._providers[idx]):
            provs = yield from self.node.dht.find_providers(
                shard_key(self.fleet, idx))
            fresh = [p for p in provs if p.peer_id != self.node.peer_id]
            if fresh or refresh:
                self._providers[idx] = fresh
            self._resolved_at[idx] = self.node.sim.now
        return self._providers.get(idx, [])

    def _drop_provider(self, idx: int, info: PeerInfo) -> None:
        provs = self._providers.get(idx, [])
        self._providers[idx] = [p for p in provs
                                if p.peer_id != info.peer_id]

    # -- v1 surface ----------------------------------------------------------
    def _call_shard(self, idx: int, payload: Dict[str, Any]) -> Generator:
        provs = yield from self._resolve(idx)
        if payload.get("op") == "score" and len(provs) > 1:
            # stateless + idempotent: hedge the tail on the next-best replica
            resp = yield from self._hedged_score(idx, provs, payload)
            if resp is not None:
                return resp
            provs = yield from self._resolve(idx, refresh=True)
        last: Optional[Exception] = None
        for round_ in range(2):
            ranked = self.router.rank(idx, list(provs),
                                      lambda p: p.peer_id)
            for info in ranked:
                self.stats["calls"] += 1
                t0 = self.node.sim.now
                self.router.begin(idx, info.peer_id)
                try:
                    stub = self.node.stub(InferenceService, info,
                                          scope=f"{self.fleet}.{idx}")
                    resp = yield from stub.infer(payload)
                    self.router.observe(idx, info.peer_id,
                                        self.node.sim.now - t0, True)
                    return resp
                except (RpcError, DialError) as e:
                    self.router.observe(idx, info.peer_id,
                                        self.node.sim.now - t0, False)
                    if (isinstance(e, ServiceError)
                            and not e.status.retryable):
                        raise     # NOT_FOUND etc: a healthy replica answered
                    last = e
                    self.stats["failovers"] += 1
                    self._drop_provider(idx, info)
                finally:
                    self.router.end(idx, info.peer_id)
            provs = yield from self._resolve(idx, refresh=True)
        raise RpcError(f"all providers for shard {idx} failed: {last}")

    def _hedged_score(self, idx: int, provs: List[PeerInfo],
                      payload: Dict[str, Any]) -> Generator:
        ranked = self.router.rank(idx, list(provs), lambda p: p.peer_id)

        def attempt(info: PeerInfo):
            def run() -> Generator:
                self.stats["calls"] += 1
                t0 = self.node.sim.now
                self.router.begin(idx, info.peer_id)
                try:
                    stub = self.node.stub(InferenceService, info,
                                          scope=f"{self.fleet}.{idx}")
                    # the dedicated score method declares idempotent=True;
                    # hedging the stateful `infer` would violate L004
                    resp = yield from stub.score(payload)
                    self.router.observe(idx, info.peer_id,
                                        self.node.sim.now - t0, True)
                    return resp
                except (RpcError, DialError):
                    self.router.observe(idx, info.peer_id,
                                        self.node.sim.now - t0, False)
                    self.stats["failovers"] += 1
                    self._drop_provider(idx, info)
                    raise
                finally:
                    self.router.end(idx, info.peer_id)
            return run

        try:
            resp = yield from hedged_call(
                self.node.sim, [attempt(p) for p in ranked[:3]],
                self.hedge_after, self.stats)
            return resp
        except (RpcError, DialError):
            return None           # caller falls back to sequential failover

    # -- v1 pipeline ops -----------------------------------------------------
    def prefill(self, tokens: np.ndarray, max_len: int) -> Generator:
        session = (self.node.host.name, next(_session_seq))
        x: Any = tokens
        for i in range(self.n_shards):
            payload = {"op": "prefill", "session": session, "x": x,
                       "max_len": max_len}
            resp = yield from self._call_shard(i, payload)
            x = resp["x"]
        return session, x                        # x = last-position logits

    def decode_step(self, session: Any, token: np.ndarray) -> Generator:
        x: Any = token
        for i in range(self.n_shards):
            payload = {"op": "decode", "session": session, "x": x}
            resp = yield from self._call_shard(i, payload)
            x = resp["x"]
        return x

    def score(self, tokens: np.ndarray) -> Generator:
        x: Any = tokens
        for i in range(self.n_shards):
            payload = {"op": "score", "x": x}
            resp = yield from self._call_shard(i, payload)
            x = resp["x"]
        return x

    def generate(self, tokens: np.ndarray, n_tokens: int) -> Generator:
        """Greedy v1 generation with mid-generation session migration: when
        a provider dies between decode steps, the session's KV state is gone
        with it — replay prompt ⊕ generated on a freshly resolved chain and
        keep going rather than losing the session."""
        max_len = tokens.shape[1] + n_tokens + 1
        session, logits = yield from self.prefill(tokens, max_len)
        out: List[np.ndarray] = []
        migrations = 0
        while len(out) < n_tokens:
            tok = np.argmax(logits, axis=-1).astype(np.int32)
            out.append(tok)
            if len(out) == n_tokens:
                break
            try:
                logits = yield from self.decode_step(session, tok)
            except (RpcError, DialError):
                migrations += 1
                if migrations > self.max_migrations:
                    raise
                self.stats["sessions_migrated"] += 1
                replay = np.concatenate(
                    [tokens] + [t[:, None] for t in out], axis=1)
                session, logits = yield from self.prefill(replay, max_len)
        return np.stack(out, axis=1)

    # -- v2 continuous-batching driver --------------------------------------
    def submit(self, tokens: np.ndarray, n_tokens: int,
               temperature: float = 0.0, seed: int = 0) -> Any:
        """Enqueue one generation request; returns an Event that succeeds
        with the generated token array (None if the session failed after
        exhausting retries)."""
        prompt = np.asarray(tokens, np.int32).reshape(1, -1)
        req = _Request(prompt, n_tokens, temperature, seed,
                       self.node.sim.event(), self.node.sim.now)
        self.stats["requests"] += 1
        self._pending.append(req)
        self._kick()
        return req.done

    def generate_concurrent(self, requests: List[Dict[str, Any]]) -> Generator:
        """Submit many requests and wait for all; each request is a dict of
        ``submit`` kwargs.  Returns the per-request token arrays."""
        events = [self.submit(**r) for r in requests]
        results = []
        for ev in events:
            res = yield ev
            results.append(res)
        return results

    def _kick(self) -> None:
        if not self._pump_alive:
            self._pump_alive = True
            self.node.sim.process(self._pump())
        elif self._wake is not None and not self._wake.triggered:
            self._wake.succeed()

    def _pump(self) -> Generator:
        """Iteration-level scheduler: start admissions as they arrive, run
        one decode round per iteration over every active session, grouped
        by provider chain (one ``step`` RPC per shard hop per group)."""
        sim = self.node.sim
        try:
            while self._pending or self._admitting or self._active:
                while self._pending:
                    req = self._pending.popleft()
                    self._admitting.add(req)
                    sim.process(self._admit(req))
                if self._active:
                    yield from self._decode_round()
                else:
                    self._wake = sim.event()
                    yield sim.any_of([self._wake, sim.timeout(0.02)])
                    self._wake = None
        finally:
            self._pump_alive = False
        return None

    def _admit(self, req: _Request) -> Generator:
        try:
            status = yield from self._try_admit(req)
        except (RpcError, DialError):
            status = "retry"
        finally:
            self._admitting.discard(req)
        if status == "active":
            self._active.append(req)
        elif status == "retry":
            req.attempts += 1
            if req.attempts >= self.max_session_attempts:
                self._fail(req)
            else:
                yield self.node.sim.timeout(0.1 * req.attempts)
                self._pending.append(req)
        self._kick()
        return None

    def _try_admit(self, req: _Request) -> Generator:
        """Route a chain through the shards and prefill (or replay) the
        request on it.  Returns "active", "done", or "retry"."""
        sid = (self.node.host.name, next(_session_seq))
        x: Any = np.concatenate(
            [req.prompt,
             np.asarray(req.generated, np.int32).reshape(1, -1)], axis=1)
        max_len = req.prompt.shape[1] + req.n_tokens + 1
        chain: List[PeerInfo] = []
        for i in range(self.n_shards):
            provs = yield from self._resolve(i)
            if not provs:
                provs = yield from self._resolve(i, refresh=True)
            resp = None
            for info in self.router.rank(i, list(provs),
                                         lambda p: p.peer_id):
                self.stats["calls"] += 1
                t0 = self.node.sim.now
                self.router.begin(i, info.peer_id)
                try:
                    stub = self.node.stub(InferenceV2Service, info,
                                          scope=f"{self.fleet}.{i}")
                    resp = yield from stub.open(
                        {"session": sid, "x": x, "max_len": max_len})
                    self.router.observe(i, info.peer_id,
                                        self.node.sim.now - t0, True)
                    chain.append(info)
                    break
                except (RpcError, DialError):
                    self.router.observe(i, info.peer_id,
                                        self.node.sim.now - t0, False)
                    self.stats["failovers"] += 1
                    self._drop_provider(i, info)
                finally:
                    self.router.end(i, info.peer_id)
            if resp is None:
                self._spawn_close(sid, chain)
                return "retry"
            x = resp["x"]
        req.session = sid
        req.chain = chain
        req.generated.append(self._sample(req, np.asarray(x)[0]))
        if len(req.generated) >= req.n_tokens:
            self._finish(req, in_active=False)
            return "done"
        return "active"

    def _decode_round(self) -> Generator:
        groups: Dict[Tuple, List[_Request]] = {}
        for req in list(self._active):
            key = tuple(p.peer_id for p in req.chain)
            groups.setdefault(key, []).append(req)
        procs = [self.node.sim.process(self._step_group(reqs))
                 for reqs in groups.values()]
        for p in procs:
            yield p
        return None

    def _step_group(self, reqs: List[_Request]) -> Generator:
        """One decode iteration for every session pinned to one chain: a
        single batched ``step`` RPC per shard hop.  Providers that died take
        the whole group to migration; sessions a provider no longer holds
        (post-restart) migrate individually via the ``served`` list."""
        chain = reqs[0].chain
        live = list(reqs)
        x: Any = np.asarray([r.generated[-1] for r in live], np.int32)
        for i, info in enumerate(chain):
            payload = {"sessions": [r.session for r in live], "x": x}
            self.stats["calls"] += 1
            t0 = self.node.sim.now
            self.router.begin(i, info.peer_id)
            try:
                stub = self.node.stub(InferenceV2Service, info,
                                      scope=f"{self.fleet}.{i}")
                resp = yield from stub.step(payload)
                self.router.observe(i, info.peer_id,
                                    self.node.sim.now - t0, True)
            except (RpcError, DialError):
                self.router.observe(i, info.peer_id,
                                    self.node.sim.now - t0, False)
                self.stats["failovers"] += 1
                self._drop_provider(i, info)
                for r in live:
                    self._migrate(r)
                return None
            finally:
                self.router.end(i, info.peer_id)
            served = {tuple(s) for s in resp["served"]}
            missing = [r for r in live if r.session not in served]
            for r in missing:
                self._migrate(r)
            # response rows align with the engine's served order, which is
            # the payload order filtered to sessions the shard still holds
            live = [r for r in live if r.session in served]
            if not live:
                return None
            x = resp["x"]
        for r, row in zip(live, x):
            r.generated.append(self._sample(r, row))
            if len(r.generated) >= r.n_tokens:
                self._finish(r)
        return None

    def _sample(self, req: _Request, logits: np.ndarray) -> int:
        if req.temperature <= 0.0:
            return int(np.argmax(logits))
        z = logits.astype(np.float64) / req.temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(req.rng.choice(len(p), p=p))

    def _migrate(self, req: _Request) -> None:
        """Provider died (or lost the session) mid-generation: replay
        prompt ⊕ generated on a freshly routed chain.  Client-side sampling
        means no tokens are lost — only the dead shard's KV is recomputed."""
        if req in self._active:
            self._active.remove(req)
        self._spawn_close(req.session, req.chain)
        req.migrations += 1
        self.stats["sessions_migrated"] += 1
        req.session, req.chain = None, []
        if req.migrations > self.max_migrations:
            self._fail(req)
            return
        self._pending.append(req)
        self._kick()

    def _finish(self, req: _Request, in_active: bool = True) -> None:
        if in_active and req in self._active:
            self._active.remove(req)
        req.finished_at = self.node.sim.now
        self._spawn_close(req.session, req.chain)
        self.stats["completed"] += 1
        req.done.succeed(np.asarray(req.generated, np.int32))

    def _fail(self, req: _Request) -> None:
        self.stats["failed_sessions"] += 1
        req.done.succeed(None)

    def _spawn_close(self, sid: Any, chain: List[PeerInfo]) -> None:
        if sid is None or not chain:
            return
        self.node.sim.process(self._close_session(sid, list(chain)))

    def _close_session(self, sid: Any, chain: List[PeerInfo]) -> Generator:
        for i, info in enumerate(chain):
            try:
                stub = self.node.stub(InferenceV2Service, info,
                                      scope=f"{self.fleet}.{i}")
                yield from stub.close([sid])
            except (RpcError, DialError):
                pass              # dead provider needs no eviction
        return None


def deploy_sharded(nodes: List[LatticaNode], cfg: ModelConfig, params: Any,
                   fleet: str, replicas: int = 1, n_slots: int = 8,
                   page_size: int = 32,
                   kv_dtype: str = "fp32") -> List[ShardServer]:
    """Place ``n_shards = len(nodes) // replicas`` pipeline shards, each
    replicated ``replicas`` times across the given nodes."""
    n_shards = len(nodes) // replicas
    plan = plan_shards(cfg, n_shards)
    parts = split_params(cfg, params, plan)
    servers = []
    for r in range(replicas):
        for i, (lo, hi) in enumerate(plan):
            node = nodes[r * n_shards + i]
            module = ShardModule(cfg, parts[i], (lo, hi),
                                 is_first=(i == 0), is_last=(i == n_shards - 1))
            servers.append(ShardServer(node, cfg, fleet, i, module,
                                       n_slots=n_slots, page_size=page_size,
                                       kv_dtype=kv_dtype))
    return servers


def serve_fleet(nodes: List[LatticaNode], cfg: ModelConfig, params: Any,
                fleet: str, replicas: int = 1, n_slots: int = 8,
                page_size: int = 32, kv_dtype: str = "fp32",
                publisher: Optional[LatticaNode] = None) -> Generator:
    """Full serving bring-up: deploy shards, announce DHT providers,
    publish every shard's param sub-DAG + the serving plan into the CRDT
    plane (what :class:`~repro.serving.pressure.PressureMonitor` replicas
    fetch), and start per-server load publishing.  Returns the servers."""
    from .pressure import load_publisher, publish_serving_plan

    servers = deploy_sharded(nodes, cfg, params, fleet, replicas=replicas,
                             n_slots=n_slots, page_size=page_size,
                             kv_dtype=kv_dtype)
    for s in servers:
        yield from s.announce()
    n_shards = len(servers) // replicas
    plan = plan_shards(cfg, n_shards)
    parts = split_params(cfg, params, plan)
    pub = publisher or nodes[0]
    yield from publish_serving_plan(pub, fleet, plan, parts)
    for s in servers:
        s.node.sim.process(load_publisher(s), daemon=True)
    return servers
