"""Load-aware replica routing for the serving plane.

Replaces first-successful-dial provider choice in :class:`ShardClient`:
every (shard, provider) pair keeps an EWMA of observed call latency, an
EWMA error rate, and a live in-flight depth, and the router orders
candidate replicas by a combined score (DIT's ``ExpertStats`` load-aware
router is the exemplar design).  A small epsilon-greedy exploration share
keeps stats fresh on replicas that would otherwise never be probed again
after one bad sample.

Also provides :func:`hedged_call` — a tail-latency hedge for *idempotent*
calls: the primary attempt races a hedge timer, and when the timer fires
first a backup attempt is launched on the next-best provider; the first
success wins.  Stateful decode steps must not be hedged (a duplicate
attempt would advance a second KV cache), so the serving driver only
hedges stateless ops and handles decode failures by session migration.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Generator, Hashable, List, Optional, Tuple

from repro.core.simnet import Sim

__all__ = ["ProviderStats", "LoadAwareRouter", "hedged_call"]


class ProviderStats:
    """EWMA latency / error rate + in-flight depth for one provider."""

    __slots__ = ("latency", "error_rate", "inflight", "samples", "last_seen")

    def __init__(self) -> None:
        self.latency: Optional[float] = None   # EWMA seconds, None = no data
        self.error_rate = 0.0                  # EWMA of {0, 1} outcomes
        self.inflight = 0                      # calls currently outstanding
        self.samples = 0
        self.last_seen = 0.0

    def observe(self, latency: float, ok: bool, alpha: float, now: float) -> None:
        self.samples += 1
        self.last_seen = now
        if ok:
            self.latency = (latency if self.latency is None
                            else (1 - alpha) * self.latency + alpha * latency)
        # errors decay the same way successes do, so a recovered replica
        # earns its way back instead of being poisoned forever
        self.error_rate = (1 - alpha) * self.error_rate + alpha * (0.0 if ok else 1.0)


class LoadAwareRouter:
    """Scores (key, provider) pairs; lower score = better replica.

    ``score = ewma_latency * (1 + inflight) * (1 + error_weight * err)`` —
    queueing-theory shaped: expected completion grows with the work already
    queued on the replica, and recent failures multiply the penalty.
    Providers with no samples yet score as ``cold_latency`` so fresh
    replicas (e.g. pressure-spawned ones) are tried early but do not
    preempt a provider with a proven fast path.
    """

    def __init__(self, sim: Sim, alpha: float = 0.3, error_weight: float = 8.0,
                 explore: float = 0.05, cold_latency: float = 20e-3):
        self.sim = sim
        self.alpha = alpha
        self.error_weight = error_weight
        self.explore = explore
        self.cold_latency = cold_latency
        self._stats: Dict[Tuple[Hashable, Hashable], ProviderStats] = {}
        self.stats = {"picks": 0, "explored": 0, "observed": 0, "errors": 0}

    def _entry(self, key: Hashable, provider: Hashable) -> ProviderStats:
        entry = self._stats.get((key, provider))
        if entry is None:
            entry = self._stats[(key, provider)] = ProviderStats()
        return entry

    # -- accounting ---------------------------------------------------------
    def begin(self, key: Hashable, provider: Hashable) -> None:
        self._entry(key, provider).inflight += 1

    def end(self, key: Hashable, provider: Hashable) -> None:
        entry = self._entry(key, provider)
        entry.inflight = max(0, entry.inflight - 1)

    def observe(self, key: Hashable, provider: Hashable, latency: float,
                ok: bool) -> None:
        self.stats["observed"] += 1
        if not ok:
            self.stats["errors"] += 1
        self._entry(key, provider).observe(latency, ok, self.alpha,
                                           self.sim.now)

    def score(self, key: Hashable, provider: Hashable) -> float:
        entry = self._stats.get((key, provider))
        if entry is None or entry.latency is None:
            lat, err, infl = self.cold_latency, (entry.error_rate if entry
                                                 else 0.0), (entry.inflight
                                                             if entry else 0)
        else:
            lat, err, infl = entry.latency, entry.error_rate, entry.inflight
        return lat * (1.0 + infl) * (1.0 + self.error_weight * err)

    # -- choice -------------------------------------------------------------
    def rank(self, key: Hashable, providers: List[Any],
             provider_id: Callable[[Any], Hashable] = lambda p: p) -> List[Any]:
        """Candidates ordered best-first (the hedging/failover order).
        With probability ``explore`` the top two are swapped so second-best
        replicas keep producing fresh samples."""
        self.stats["picks"] += 1
        ordered = sorted(providers,
                         key=lambda p: self.score(key, provider_id(p)))
        if (len(ordered) > 1 and self.explore > 0
                and self.sim.rng.random() < self.explore):
            self.stats["explored"] += 1
            ordered[0], ordered[1] = ordered[1], ordered[0]
        return ordered

    def pick(self, key: Hashable, providers: List[Any],
             provider_id: Callable[[Any], Hashable] = lambda p: p) -> Any:
        return self.rank(key, providers, provider_id)[0]


def hedged_call(sim: Sim, attempts: List[Callable[[], Generator]],
                hedge_after: float, stats: Optional[Dict[str, int]] = None,
                ) -> Generator:
    """Run ``attempts[0]``; if it has not finished after ``hedge_after``
    seconds, launch the next attempt in parallel (and so on), returning the
    first success.  Raises the last failure only once every launched
    attempt has failed.  Only safe for idempotent work."""
    procs = []
    next_attempt = 0
    last_exc: Optional[BaseException] = None

    def launch() -> None:
        nonlocal next_attempt
        procs.append(sim.process(attempts[next_attempt]()))
        next_attempt += 1

    launch()
    while True:
        waits: List[Any] = list(procs)
        timer = None
        if next_attempt < len(attempts):
            timer = sim.timeout(hedge_after)
            waits.append(timer)
        try:
            idx, value = yield sim.any_of(waits)
        except BaseException as exc:  # noqa: BLE001 — one attempt failed
            last_exc = exc
            # drop finished-failed procs; keep the rest racing
            procs[:] = [p for p in procs if not p.triggered]
            if procs:
                continue
            if next_attempt < len(attempts):
                launch()
                continue
            raise
        if timer is not None and idx == len(waits) - 1:
            if stats is not None:
                stats["hedged"] = stats.get("hedged", 0) + 1
            launch()
            continue
        return value
