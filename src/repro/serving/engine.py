"""Single-process generation engine: prefill + greedy/temperature decode.

Used directly by examples and wrapped by the sharded serving layer."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelOps, ops_for
from repro.models.config import ModelConfig


class GenerationEngine:
    def __init__(self, cfg: ModelConfig, params: Any,
                 max_len: int = 4096, dtype: Any = jnp.float32):
        self.cfg = cfg
        self.params = params
        self.ops: ModelOps = ops_for(cfg)
        self.max_len = max_len
        self.dtype = dtype
        self._prefill = jax.jit(
            lambda p, b, c: self.ops.prefill(p, cfg, b, c))
        self._decode = jax.jit(
            lambda p, t, c: self.ops.decode_step(p, cfg, t, c))

    def generate(self, batch: Dict[str, jax.Array], n_tokens: int,
                 temperature: float = 0.0, seed: int = 0,
                 ) -> Tuple[np.ndarray, Dict[str, float]]:
        B = batch["tokens"].shape[0]
        extra = self.cfg.n_patches if self.cfg.arch == "vlm" else 0
        cache = self.ops.init_cache(
            self.cfg, B, batch["tokens"].shape[1] + extra + n_tokens,
            self.dtype)
        logits, cache = self._prefill(self.params, batch, cache)
        key = jax.random.PRNGKey(seed)
        out = []
        for i in range(n_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits.astype(jnp.float32) / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            tok = tok.astype(jnp.int32)
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, tok, cache)
        return np.stack(out, axis=1), {"generated": n_tokens * B}
