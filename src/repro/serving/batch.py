"""Continuous-batching engine for one pipeline shard (Orca-style).

A :class:`BatchEngine` owns a fixed table of decode *slots*.  Each slot
holds one session's KV cache, allocated in pages of ``page_size`` tokens
and grown on demand, so a shard admits new sequences and evicts finished
ones at every decode step — prefill and decode interleave across
concurrent sessions instead of queueing whole requests.

Admission is FIFO: when the slot table is full, ``open`` parks the caller
on a queue event and a freed slot is handed directly to the oldest
waiter (no barging).  The engine is deliberately yield-free apart from
that admission wait; compute methods return the floating-point op count
alongside the result so the RPC handler charges simulated CPU time
*once per batched call* — which is exactly where continuous batching
wins: one wire message and one per-message CPU charge amortized over
every active session instead of per session per token.

Numerics are intentionally identical to the one-session-at-a-time v1
path (per-slot batch=1 apply), so greedy decode through the batched
plane matches :class:`repro.serving.engine.GenerationEngine` bit-for-bit.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Deque, Dict, Generator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simnet import Sim

__all__ = ["BatchEngine", "SlotState"]


class SlotState:
    """One occupied decode slot: a session pinned to a paged KV cache."""

    __slots__ = ("session", "slot", "cache", "capacity", "max_len",
                 "last_used")

    def __init__(self, session: Any, slot: int, cache: Dict[str, Any],
                 capacity: int, max_len: int, now: float):
        self.session = session
        self.slot = slot
        self.cache = cache
        self.capacity = capacity
        self.max_len = max_len
        self.last_used = now


class BatchEngine:
    def __init__(self, module: Any, sim: Sim, n_slots: int = 8,
                 page_size: int = 32):
        self.module = module
        self.sim = sim
        self.n_slots = n_slots
        self.page_size = page_size
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._slot_last_session: List[Any] = [None] * n_slots
        self.by_session: Dict[Any, SlotState] = {}
        # FIFO of (session, event) waiting for a slot; a freed slot is
        # succeed()ed straight into the head waiter's event
        self._queue: Deque[Tuple[Any, Any]] = deque()
        # params are closed over as jit constants; shapes key the trace
        # cache, so steady-state decode is one compiled call per slot
        self._apply = jax.jit(
            lambda x, pos, cache: module.apply(x, pos, cache))
        self.stats = {
            "admitted": 0, "evicted": 0, "prefills": 0, "steps": 0,
            "step_sessions": 0, "queue_peak": 0, "slot_reuse": 0,
            "pages": 0, "pages_peak": 0, "idle_evicted": 0,
        }

    # -- occupancy (what pressure publishing reports) -----------------------
    @property
    def slots_used(self) -> int:
        return self.n_slots - len(self._free)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    # -- paged cache --------------------------------------------------------
    def _pages_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def _alloc_cache(self, n_tokens: int) -> Tuple[Dict[str, Any], int]:
        cap = self._pages_for(n_tokens) * self.page_size
        cache = self.module.init_cache(1, cap)
        return cache, cap

    def _ensure_capacity(self, st: SlotState, need: int) -> None:
        """Grow the slot's cache by whole pages until it can hold ``need``
        tokens.  Growth pads each leaf along its (single) capacity axis,
        so it is arch-agnostic: SSM/recurrent leaves keep their shapes and
        window-limited caches stop growing at the window."""
        if need <= st.capacity:
            return
        new_cap = self._pages_for(need) * self.page_size
        fresh = self.module.init_cache(1, new_cap)

        def merge(old: jax.Array, new: jax.Array) -> jax.Array:
            if old.shape == new.shape:
                return old
            diff = [d for d in range(old.ndim) if old.shape[d] != new.shape[d]]
            assert len(diff) == 1, (old.shape, new.shape)
            ax = diff[0]
            pad = [(0, new.shape[d] - old.shape[d]) if d == ax else (0, 0)
                   for d in range(old.ndim)]
            return jnp.pad(old, pad)

        grown = jax.tree.map(merge, st.cache["layers"], fresh["layers"])
        st.cache = {"len": st.cache["len"], "layers": grown}
        self.stats["pages"] += (new_cap - st.capacity) // self.page_size
        self.stats["pages_peak"] = max(self.stats["pages_peak"],
                                       self._pages_in_use())
        st.capacity = new_cap

    def _pages_in_use(self) -> int:
        return sum(st.capacity // self.page_size
                   for st in self.by_session.values())

    # -- admission / eviction ------------------------------------------------
    def open(self, session: Any, x: np.ndarray, max_len: int) -> Generator:
        """Admit ``session`` (waiting FIFO for a slot if the table is full)
        and run its prefill.  Returns ``(out, flops)``; idempotent per
        session id — re-opening replaces the previous cache, so a retried
        admission cannot leak a slot."""
        if session in self.by_session:
            slot = self.by_session.pop(session).slot
        elif self._free:
            slot = self._free.pop()
        else:
            ev = self.sim.event()
            self._queue.append((session, ev))
            self.stats["queue_peak"] = max(self.stats["queue_peak"],
                                           len(self._queue))
            slot = yield ev
        out, flops = self._prefill(session, slot, x, max_len)
        return out, flops

    def close(self, sessions: List[Any]) -> int:
        n = 0
        for sid in list(sessions):
            if sid in self.by_session:
                self._release(sid)
                n += 1
        return n

    def reap_idle(self, ttl: float) -> int:
        """Evict sessions untouched for ``ttl`` sim-seconds (crashed or
        timed-out clients must not pin slots forever)."""
        now = self.sim.now
        stale = [sid for sid, st in self.by_session.items()
                 if now - st.last_used > ttl]
        for sid in stale:
            self._release(sid)
            self.stats["idle_evicted"] += 1
        return len(stale)

    def fail_waiters(self, exc: BaseException) -> int:
        """Crash path: wake every queued admission with ``exc``.  A dead
        server must not pin parked callers until their RPC deadline — the
        error surfaces immediately so the client re-admits elsewhere."""
        n = 0
        while self._queue:
            _, ev = self._queue.popleft()
            ev.fail(exc)
            n += 1
        return n

    def _release(self, session: Any) -> None:
        st = self.by_session.pop(session)
        self.stats["evicted"] += 1
        if self._queue:
            _, ev = self._queue.popleft()
            ev.succeed(st.slot)       # direct handoff keeps admission FIFO
        else:
            self._free.append(st.slot)

    # -- compute ------------------------------------------------------------
    def _positions(self, base: Any, B: int, S: int) -> jax.Array:
        if S == 1:
            pos = jnp.broadcast_to(jnp.asarray(base)[None, None],
                                   (B, 1)).astype(jnp.int32)
        else:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                   (B, S))
        if self.module.cfg.mrope:
            pos = jnp.broadcast_to(pos[None], (3,) + pos.shape)
        return pos

    def _prefill(self, session: Any, slot: int, x: np.ndarray,
                 max_len: int) -> Tuple[np.ndarray, float]:
        m = self.module
        self.stats["prefills"] += 1
        self.stats["admitted"] += 1
        if self._slot_last_session[slot] not in (None, session):
            self.stats["slot_reuse"] += 1
        self._slot_last_session[slot] = session
        xj = jnp.asarray(x)
        if m.is_first and xj.dtype == jnp.int32:
            xj = m.embed(xj)
        S = xj.shape[1]
        cache, cap = self._alloc_cache(S + 1)
        st = SlotState(session, slot, cache, cap, max_len, self.sim.now)
        self.by_session[session] = st
        self.stats["pages"] += cap // self.page_size
        self.stats["pages_peak"] = max(self.stats["pages_peak"],
                                       self._pages_in_use())
        out, st.cache = self._apply(xj, self._positions(0, 1, S), st.cache)
        if m.is_last:
            out = m.head(out[:, -1:])[:, 0]       # (1, vocab)
        return np.asarray(out), m.flops(S)

    def step(self, sessions: List[Any], x: np.ndarray,
             evict: Optional[List[Any]] = None,
             ) -> Tuple[np.ndarray, List[Any], float]:
        """One decode iteration over a batch of sessions.

        ``x`` is row-aligned with ``sessions``: int32 token ids ``(M,)``
        on the first shard, activations ``(M, d_model)`` downstream.
        Sessions the engine no longer holds are skipped rather than
        failing the whole batch; the returned ``served`` list tells the
        driver which rows came back (missing ones get migrated).
        ``evict`` frees finished sessions *before* compute, so their
        slots are available to queued admissions within the same step.
        """
        if evict:
            self.close(evict)
        m = self.module
        self.stats["steps"] += 1
        served: List[Any] = []
        outs: List[np.ndarray] = []
        flops = 0.0
        for i, sid in enumerate(sessions):
            st = self.by_session.get(sid)
            if st is None:
                continue
            st.last_used = self.sim.now
            xi = jnp.asarray(x[i])[None]          # (1,) tokens or (1, D)
            if m.is_first and xi.dtype == jnp.int32:
                xi = m.embed(xi[:, None])
            else:
                xi = xi[:, None]                  # (1, 1, D)
            cur = int(st.cache["len"])
            self._ensure_capacity(st, cur + 1)
            out, st.cache = self._apply(
                xi, self._positions(cur, 1, 1), st.cache)
            if m.is_last:
                out = m.head(out)[:, 0]           # (1, vocab)
            else:
                out = out[:, 0]                   # (1, d_model)
            outs.append(np.asarray(out[0]))
            served.append(sid)
            flops += m.flops(1)
        self.stats["step_sessions"] += len(served)
        out_arr = (np.stack(outs) if outs
                   else np.zeros((0, 1), dtype=np.float32))
        return out_arr, served, flops

    def slot_of(self, session: Any) -> Optional[int]:
        st = self.by_session.get(session)
        return None if st is None else st.slot
