"""Continuous-batching engine for one pipeline shard (Orca-style).

A :class:`BatchEngine` owns a fixed table of decode *slots*.  Each slot
holds one session's KV cache, allocated in pages of ``page_size`` tokens
and grown on demand, so a shard admits new sequences and evicts finished
ones at every decode step — prefill and decode interleave across
concurrent sessions instead of queueing whole requests.

Admission is FIFO: when the slot table is full, ``open`` parks the caller
on a queue event and a freed slot is handed directly to the oldest
waiter (no barging).  The engine is deliberately yield-free apart from
that admission wait; compute methods return a simulated *cost in
seconds* alongside the result so the RPC handler charges CPU time
*once per batched call*.

Two decode paths share the slot table:

* **Fused paged decode** (attention-family archs: dense/moe/vlm/audio,
  no mrope, no sliding window).  KV lives in an engine-owned *page
  pool* — per layer ``(P, page, Hk, hd)`` numpy arrays plus a free-page
  list — and each slot holds a block table of page ids.  One jitted
  forward advances *every* live slot per step: per layer, project
  q/k/v for the whole batch, run paged single-query attention
  (:mod:`repro.kernels.paged_attention`) over the block tables, and
  return the new k/v rows, which the engine appends into the pool
  host-side.  The unfused path re-reads the shard weights once per
  session per token; the fused path reads them once per *batch* — in a
  roofline cost model that is where batched decode actually wins.
  ``kv_dtype="int8"`` stores pool pages quantized (per-page per-kv-head
  scales, dequantized inside the attention kernel) for ~4x fewer
  cache-resident bytes; the partial (current) page keeps an fp32
  staging master per slot, so requantization never compounds error.

* **Per-slot fallback** (ssm/hybrid/mrope/windowed): the original
  batch=1 ``module.apply`` loop with whole-page dense cache growth,
  numerics bit-identical to the v1 path.

Page accounting is exact in both paths: the pool's free list makes
alloc/free symmetric by construction, the fallback keeps a running
counter (no O(slots) rescans on grow), and ``stats["pages"]`` always
equals pages currently in use (0 when every session is closed).

The fp32 fused path is argmax-equivalent to the v1 path (same
projection/rope/mask/softmax formulation on the same cached values), so
greedy decode through the batched plane still matches
:class:`repro.serving.engine.GenerationEngine`.
"""

from __future__ import annotations

import itertools
from collections import deque
from typing import Any, Deque, Dict, Generator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simnet import Sim
from repro.kernels.paged_attention import paged_attention_jnp
from repro.models.common import apply_rope, rms_norm, run_mlp
from repro.models.moe import run_moe

__all__ = ["BatchEngine", "KVPool", "SlotState", "PEER_FLOPS", "PEER_BW"]

#: assumed accelerator throughput per serving peer, for simulated latency
PEER_FLOPS = 2.0e11
#: assumed accelerator memory bandwidth per serving peer (bytes/s); decode
#: is bandwidth-bound, so step cost is max(compute, weight+KV traffic)
PEER_BW = 8.0e10

#: archs the fused paged-decode path supports (attention-family blocks)
_FUSED_ARCHS = ("dense", "moe", "vlm", "audio")

#: distinguishes each engine's simsan leak gauge within one Sim
_ENGINE_SEQ = itertools.count()


def _quant_page_int8(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric int8 quantization of one page ``(L, page, Hk, hd)`` with
    per-(layer, kv-head) scales: |x - x̂| <= absmax/254 elementwise."""
    amax = np.abs(x).max(axis=(1, 3))
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.rint(x / scale[:, None, :, None]).astype(np.int8)
    return q, scale


class KVPool:
    """Shared paged KV storage for one shard's fused decode path.

    Per layer ``k/v`` pools of shape ``(L, P, page, Hk, hd)`` grown
    geometrically, plus a free-page list — alloc and free are exact and
    symmetric.  ``quant`` stores int8 pages with per-(page, kv-head)
    dequant scales ``(L, P, Hk)``.
    """

    def __init__(self, n_layers: int, n_kv_heads: int, head_dim: int,
                 page_size: int, quant: bool = False):
        self.L = n_layers
        self.Hk = n_kv_heads
        self.hd = head_dim
        self.page = page_size
        self.quant = quant
        self.n_pages = 0
        self._free: List[int] = []
        dt = np.int8 if quant else np.float32
        self.kp = np.zeros((self.L, 0, page_size, self.Hk, self.hd), dt)
        self.vp = np.zeros_like(self.kp)
        self.ks = (np.ones((self.L, 0, self.Hk), np.float32)
                   if quant else None)
        self.vs = (np.ones((self.L, 0, self.Hk), np.float32)
                   if quant else None)

    @property
    def page_bytes(self) -> int:
        """Cache-resident bytes of one allocated page (k+v, + scales)."""
        per = self.L * self.page * self.Hk * self.hd * self.kp.dtype.itemsize
        scales = 2 * self.L * self.Hk * 4 if self.quant else 0
        return 2 * per + scales

    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def bytes_in_use(self) -> int:
        return self.pages_in_use() * self.page_bytes

    def _grow(self, min_total: int) -> None:
        total = max(min_total, self.n_pages * 2, 8)
        add = total - self.n_pages

        def ext(a: np.ndarray, fill: float = 0.0) -> np.ndarray:
            blk = np.full((self.L, add) + a.shape[2:], fill, a.dtype)
            return np.concatenate([a, blk], axis=1)

        self.kp = ext(self.kp)
        self.vp = ext(self.vp)
        if self.quant:
            self.ks = ext(self.ks, 1.0)
            self.vs = ext(self.vs, 1.0)
        self._free.extend(range(self.n_pages, total))
        self.n_pages = total

    def alloc(self, n: int) -> List[int]:
        if len(self._free) < n:
            self._grow(self.n_pages + n - len(self._free))
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]) -> None:
        self._free.extend(pages)

    def write_page(self, pid: int, k: np.ndarray, v: np.ndarray) -> None:
        """Store one full page ``(L, page, Hk, hd)`` fp32 (zero-padded
        past the valid tokens — zeros quantize to 0 under any scale)."""
        if self.quant:
            self.kp[:, pid], self.ks[:, pid] = _quant_page_int8(k)
            self.vp[:, pid], self.vs[:, pid] = _quant_page_int8(v)
        else:
            self.kp[:, pid] = k
            self.vp[:, pid] = v

    def write_tokens(self, pid: int, offset: int, k: np.ndarray,
                     v: np.ndarray) -> None:
        """fp32 pools only: in-place write of ``t`` tokens at ``offset``."""
        t = k.shape[1]
        self.kp[:, pid, offset:offset + t] = k
        self.vp[:, pid, offset:offset + t] = v


class SlotState:
    """One occupied decode slot: a session pinned to a paged KV cache."""

    __slots__ = ("session", "slot", "cache", "capacity", "max_len",
                 "last_used", "length", "pages", "k_tail", "v_tail")

    def __init__(self, session: Any, slot: int, cache: Optional[Dict[str, Any]],
                 capacity: int, max_len: int, now: float):
        self.session = session
        self.slot = slot
        self.cache = cache            # dense per-slot cache (fallback path)
        self.capacity = capacity
        self.max_len = max_len
        self.last_used = now
        self.length = 0               # cached tokens (fused path)
        self.pages: List[int] = []    # pool page ids (fused path)
        self.k_tail: Optional[np.ndarray] = None   # fp32 staging master for
        self.v_tail: Optional[np.ndarray] = None   # the partial page (int8)


def _fused_block(cfg: Any, p: Any, x: jax.Array, positions: jax.Array,
                 bt: jax.Array, lengths: jax.Array, kp: jax.Array,
                 vp: jax.Array, ks: Optional[jax.Array],
                 vs: Optional[jax.Array],
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One attention-family block for a batch of single-token rows, with
    KV read from the page pool.  Mirrors ``decoder.run_block``'s dense
    decode math exactly (rms_norm -> q/k/v -> qk_norm -> rope -> masked
    softmax over the cache -> wo -> residual -> ln2 -> mlp/moe)."""
    ap = p["attn"]
    B = x.shape[0]
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (h @ ap["wq"]).reshape(B, 1, H, hd)
    k = (h @ ap["wk"]).reshape(B, 1, Hk, hd)
    v = (h @ ap["wv"]).reshape(B, 1, Hk, hd)
    if cfg.qk_norm:
        q = rms_norm(q, ap["q_norm"], cfg.norm_eps)
        k = rms_norm(k, ap["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    attn = paged_attention_jnp(q[:, 0], kp, vp, bt, lengths,
                               k[:, 0], v[:, 0], ks, vs)     # (B, H, hd)
    x = x + attn.reshape(B, 1, H * hd) @ ap["wo"]
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.arch == "moe":
        ffn, _ = run_moe(p["moe"], cfg, h2, use_kernel=cfg.use_flash_kernel,
                         no_drop=True)
    else:
        ffn = run_mlp(p["mlp"], h2)
    return x + ffn, k[:, 0], v[:, 0]


class BatchEngine:
    def __init__(self, module: Any, sim: Sim, n_slots: int = 8,
                 page_size: int = 32, kv_dtype: str = "fp32",
                 fused: Optional[bool] = None):
        if kv_dtype not in ("fp32", "int8"):
            raise ValueError(f"unknown kv_dtype {kv_dtype!r}")
        self.module = module
        self.sim = sim
        self.n_slots = n_slots
        self.page_size = page_size
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._slot_last_session: List[Any] = [None] * n_slots
        self.by_session: Dict[Any, SlotState] = {}
        # FIFO of (session, event) waiting for a slot; a freed slot is
        # succeed()ed straight into the head waiter's event
        self._queue: Deque[Tuple[Any, Any]] = deque()
        # params are closed over as jit constants; shapes key the trace
        # cache, so steady-state decode is one compiled call per shape
        self._apply = jax.jit(
            lambda x, pos, cache: module.apply(x, pos, cache))
        supported = self._supports_fused(module)
        self.fused = supported if fused is None else (fused and supported)
        self.kv_dtype = kv_dtype if self.fused else "fp32"
        self._pool: Optional[KVPool] = None
        self._fallback_pages = 0      # exact page counter for the dense path
        if self.fused:
            cfg = module.cfg
            self._pool = KVPool(module.n_layers, cfg.n_kv_heads, cfg.hd,
                                page_size, quant=(self.kv_dtype == "int8"))
            self._fused_apply = jax.jit(self._build_fused_apply())
        self.stats = {
            "admitted": 0, "evicted": 0, "prefills": 0, "steps": 0,
            "step_sessions": 0, "queue_peak": 0, "slot_reuse": 0,
            "pages": 0, "pages_peak": 0, "idle_evicted": 0,
        }
        sim.register_leak_check(
            f"kv.pages:{next(_ENGINE_SEQ)}", self._pages_in_use)

    @staticmethod
    def _supports_fused(module: Any) -> bool:
        cfg = getattr(module, "cfg", None)
        return (cfg is not None
                and cfg.arch in _FUSED_ARCHS
                and not cfg.mrope
                and cfg.window == 0
                and hasattr(module, "_layer_params"))

    def _build_fused_apply(self):
        m = self.module
        cfg = m.cfg

        def fused(x, positions, bt, lengths, kp, vp, ks, vs):
            if m.is_first and x.dtype == jnp.int32:
                h = m.embed(x[:, None])                      # (M, 1, D)
            else:
                h = x[:, None, :]
            new_k: List[jax.Array] = []
            new_v: List[jax.Array] = []
            for j in range(m.n_layers):
                lp = m._layer_params(j)
                h, kn, vn = _fused_block(
                    cfg, lp, h, positions, bt, lengths, kp[j], vp[j],
                    None if ks is None else ks[j],
                    None if vs is None else vs[j])
                new_k.append(kn)
                new_v.append(vn)
            out = m.head(h)[:, 0] if m.is_last else h[:, 0]
            return out, jnp.stack(new_k), jnp.stack(new_v)

        return fused

    # -- occupancy (what pressure publishing reports) -----------------------
    @property
    def slots_used(self) -> int:
        return self.n_slots - len(self._free)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    # -- paged cache --------------------------------------------------------
    def _pages_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def _alloc_cache(self, n_tokens: int) -> Tuple[Dict[str, Any], int]:
        cap = self._pages_for(n_tokens) * self.page_size
        cache = self.module.init_cache(1, cap)
        return cache, cap

    def _ensure_capacity(self, st: SlotState, need: int) -> None:
        """Grow the slot's dense cache by whole pages until it can hold
        ``need`` tokens.  Growth pads each leaf along its (single)
        capacity axis, so it is arch-agnostic: SSM/recurrent leaves keep
        their shapes and window-limited caches stop growing at the
        window."""
        if need <= st.capacity:
            return
        new_cap = self._pages_for(need) * self.page_size
        fresh = self.module.init_cache(1, new_cap)

        def merge(old: jax.Array, new: jax.Array) -> jax.Array:
            if old.shape == new.shape:
                return old
            diff = [d for d in range(old.ndim) if old.shape[d] != new.shape[d]]
            assert len(diff) == 1, (old.shape, new.shape)
            ax = diff[0]
            pad = [(0, new.shape[d] - old.shape[d]) if d == ax else (0, 0)
                   for d in range(old.ndim)]
            return jnp.pad(old, pad)

        grown = jax.tree.map(merge, st.cache["layers"], fresh["layers"])
        st.cache = {"len": st.cache["len"], "layers": grown}
        self._fallback_pages += (new_cap - st.capacity) // self.page_size
        st.capacity = new_cap
        self._note_pages()

    def _pages_in_use(self) -> int:
        if self.fused:
            return self._pool.pages_in_use()
        return self._fallback_pages

    def _note_pages(self) -> None:
        used = self._pages_in_use()
        self.stats["pages"] = used
        if used > self.stats["pages_peak"]:
            self.stats["pages_peak"] = used

    # -- cost model ---------------------------------------------------------
    def _weight_bytes(self) -> float:
        wb = getattr(self.module, "weight_bytes", None)
        if callable(wb):
            return float(wb())
        # flops(1) = 2 * params-touched; fp32 params = 2 bytes per flop
        return 2.0 * self.module.flops(1)

    def _slot_kv_bytes(self, st: SlotState) -> float:
        if self.fused:
            b = len(st.pages) * self._pool.page_bytes
            if st.k_tail is not None:
                b += st.k_tail.nbytes + st.v_tail.nbytes
            return float(b)
        if st.cache is None:
            return 0.0
        return float(sum(leaf.nbytes
                         for leaf in jax.tree.leaves(st.cache["layers"])))

    def kv_bytes(self) -> float:
        """Current cache-resident bytes across all live slots (pool pages
        + fp32 staging tails, or dense per-slot caches)."""
        if self.fused:
            b = float(self._pool.bytes_in_use())
            for st in self.by_session.values():
                if st.k_tail is not None:
                    b += st.k_tail.nbytes + st.v_tail.nbytes
            return b
        return sum(self._slot_kv_bytes(st) for st in self.by_session.values())

    def _cost(self, flops: float, bytes_moved: float) -> float:
        """Roofline step time: compute-bound or bandwidth-bound."""
        return max(flops / PEER_FLOPS, bytes_moved / PEER_BW)

    # -- admission / eviction ------------------------------------------------
    def open(self, session: Any, x: np.ndarray, max_len: int) -> Generator:
        """Admit ``session`` (waiting FIFO for a slot if the table is full)
        and run its prefill.  Returns ``(out, cost_seconds)``; idempotent
        per session id — re-opening replaces the previous cache (and frees
        its pages), so a retried admission cannot leak a slot or a page."""
        if session in self.by_session:
            old = self.by_session.pop(session)
            slot = old.slot
            self._free_slot_storage(old)
        elif self._free:
            slot = self._free.pop()
        else:
            ev = self.sim.event()
            self._queue.append((session, ev))
            self.stats["queue_peak"] = max(self.stats["queue_peak"],
                                           len(self._queue))
            slot = yield ev
        out, cost = self._prefill(session, slot, x, max_len)
        return out, cost

    def close(self, sessions: List[Any]) -> int:
        n = 0
        for sid in list(sessions):
            if sid in self.by_session:
                self._release(sid)
                n += 1
        return n

    def reap_idle(self, ttl: float) -> int:
        """Evict sessions untouched for ``ttl`` sim-seconds (crashed or
        timed-out clients must not pin slots forever)."""
        now = self.sim.now
        stale = [sid for sid, st in self.by_session.items()
                 if now - st.last_used > ttl]
        for sid in stale:
            self._release(sid)
            self.stats["idle_evicted"] += 1
        return len(stale)

    def fail_waiters(self, exc: BaseException) -> int:
        """Crash path: wake every queued admission with ``exc``.  A dead
        server must not pin parked callers until their RPC deadline — the
        error surfaces immediately so the client re-admits elsewhere."""
        n = 0
        while self._queue:
            _, ev = self._queue.popleft()
            ev.fail(exc)
            n += 1
        return n

    def _free_slot_storage(self, st: SlotState) -> None:
        """Return a slot's cache storage (not the slot itself)."""
        if self.fused:
            self._pool.free(st.pages)
            st.pages = []
        else:
            self._fallback_pages -= st.capacity // self.page_size
        self._note_pages()

    def _release(self, session: Any) -> None:
        st = self.by_session.pop(session)
        self.stats["evicted"] += 1
        self._free_slot_storage(st)
        if self._queue:
            _, ev = self._queue.popleft()
            ev.succeed(st.slot)       # direct handoff keeps admission FIFO
        else:
            self._free.append(st.slot)

    # -- compute ------------------------------------------------------------
    def _positions(self, base: Any, B: int, S: int) -> jax.Array:
        if S == 1:
            pos = jnp.broadcast_to(jnp.asarray(base)[None, None],
                                   (B, 1)).astype(jnp.int32)
        else:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                   (B, S))
        if self.module.cfg.mrope:
            pos = jnp.broadcast_to(pos[None], (3,) + pos.shape)
        return pos

    def _pool_write_prefill(self, st: SlotState, k: np.ndarray,
                            v: np.ndarray) -> None:
        """Copy a prefilled slot's k/v ``(L, S, Hk, hd)`` into its pool
        pages; the partial last page keeps an fp32 staging master when
        the pool is quantized (appends requantize from it, so error never
        compounds)."""
        pool, page = self._pool, self.page_size
        L, S = k.shape[0], k.shape[1]
        n_full = S // page
        for pi in range(n_full):
            sl = slice(pi * page, (pi + 1) * page)
            pool.write_page(st.pages[pi], k[:, sl], v[:, sl])
        rem = S - n_full * page
        if pool.quant:
            st.k_tail = np.zeros((L, page) + k.shape[2:], np.float32)
            st.v_tail = np.zeros_like(st.k_tail)
            if rem:
                st.k_tail[:, :rem] = k[:, n_full * page:]
                st.v_tail[:, :rem] = v[:, n_full * page:]
                pool.write_page(st.pages[n_full], st.k_tail, st.v_tail)
        elif rem:
            pool.write_tokens(st.pages[n_full], 0,
                              k[:, n_full * page:], v[:, n_full * page:])

    def _pool_append(self, st: SlotState, kn: np.ndarray,
                     vn: np.ndarray) -> None:
        """Append one token's k/v ``(L, Hk, hd)`` at position
        ``st.length`` (the page was allocated before the fused call)."""
        pool, page = self._pool, self.page_size
        pos = st.length
        off = pos % page
        pid = st.pages[pos // page]
        if pool.quant:
            if off == 0:
                st.k_tail[:] = 0.0
                st.v_tail[:] = 0.0
            st.k_tail[:, off] = kn
            st.v_tail[:, off] = vn
            pool.write_page(pid, st.k_tail, st.v_tail)
        else:
            pool.kp[:, pid, off] = kn
            pool.vp[:, pid, off] = vn
        st.length = pos + 1

    def _prefill(self, session: Any, slot: int, x: np.ndarray,
                 max_len: int) -> Tuple[np.ndarray, float]:
        m = self.module
        self.stats["prefills"] += 1
        self.stats["admitted"] += 1
        if self._slot_last_session[slot] not in (None, session):
            self.stats["slot_reuse"] += 1
        self._slot_last_session[slot] = session
        xj = jnp.asarray(x)
        if m.is_first and xj.dtype == jnp.int32:
            xj = m.embed(xj)
        S = xj.shape[1]
        cache, cap = self._alloc_cache(S + 1)
        st = SlotState(session, slot, cache, cap, max_len, self.sim.now)
        self.by_session[session] = st
        if self.fused:
            # prefill runs through the unchanged dense path, then the
            # resulting k/v move into pool pages and the dense cache is
            # dropped — steady-state decode never touches it again
            out, cache = self._apply(xj, self._positions(0, 1, S), cache)
            st.cache = None
            st.length = S
            st.pages = self._pool.alloc(cap // self.page_size)
            k = np.asarray(cache["layers"]["k"][:, 0, :S], np.float32)
            v = np.asarray(cache["layers"]["v"][:, 0, :S], np.float32)
            self._pool_write_prefill(st, k, v)
        else:
            self._fallback_pages += cap // self.page_size
            out, st.cache = self._apply(xj, self._positions(0, 1, S),
                                        st.cache)
        self._note_pages()
        if m.is_last:
            out = m.head(out[:, -1:])[:, 0]       # (1, vocab)
        cost = self._cost(m.flops(S),
                          self._weight_bytes() + self._slot_kv_bytes(st))
        return np.asarray(out), cost

    def step(self, sessions: List[Any], x: np.ndarray,
             evict: Optional[List[Any]] = None,
             ) -> Tuple[np.ndarray, List[Any], float]:
        """One decode iteration over a batch of sessions.

        ``x`` is row-aligned with ``sessions``: int32 token ids ``(M,)``
        on the first shard, activations ``(M, d_model)`` downstream.
        Sessions the engine no longer holds are skipped rather than
        failing the whole batch; the returned ``served`` list tells the
        driver which rows came back (missing ones get migrated).
        ``evict`` frees finished sessions *before* compute, so their
        slots are available to queued admissions within the same step.
        Returns ``(out, served, cost_seconds)``.
        """
        if evict:
            self.close(evict)
        self.stats["steps"] += 1
        if self.fused:
            return self._step_fused(sessions, x)
        return self._step_unfused(sessions, x)

    def _step_fused(self, sessions: List[Any], x: np.ndarray,
                    ) -> Tuple[np.ndarray, List[Any], float]:
        m = self.module
        xa = np.asarray(x)
        live: List[Tuple[int, Any, SlotState]] = []
        for i, sid in enumerate(sessions):
            st = self.by_session.get(sid)
            if st is None:
                continue
            st.last_used = self.sim.now
            need = self._pages_for(st.length + 1)
            if need > len(st.pages):           # next token starts a new page
                st.pages.extend(self._pool.alloc(need - len(st.pages)))
                st.capacity = len(st.pages) * self.page_size
                self._note_pages()
            live.append((i, sid, st))
        if not live:
            return np.zeros((0, 1), dtype=np.float32), [], 0.0
        # fixed-width batch: rows padded to n_slots, block tables padded to
        # the next power of two, so jit retraces only on pool/table growth
        M = self.n_slots
        np_pad = 1
        np_need = max(len(st.pages) for _, _, st in live)
        while np_pad < np_need:
            np_pad *= 2
        tokens = m.is_first and np.issubdtype(xa.dtype, np.integer)
        xb = (np.zeros((M,), np.int32) if tokens
              else np.zeros((M,) + xa.shape[1:], np.float32))
        bt = np.zeros((M, np_pad), np.int32)
        lengths = np.zeros((M,), np.int32)
        for r, (i, _, st) in enumerate(live):
            xb[r] = xa[i]
            bt[r, :len(st.pages)] = st.pages
            lengths[r] = st.length
        pool = self._pool
        out, nk, nv = self._fused_apply(
            jnp.asarray(xb), jnp.asarray(lengths[:, None]),
            jnp.asarray(bt), jnp.asarray(lengths),
            jnp.asarray(pool.kp), jnp.asarray(pool.vp),
            None if pool.ks is None else jnp.asarray(pool.ks),
            None if pool.vs is None else jnp.asarray(pool.vs))
        out = np.asarray(out)
        nk = np.asarray(nk, np.float32)
        nv = np.asarray(nv, np.float32)
        served: List[Any] = []
        kv_read = 0.0
        for r, (_, sid, st) in enumerate(live):
            self._pool_append(st, nk[:, r], nv[:, r])
            served.append(sid)
            kv_read += self._slot_kv_bytes(st)
        self.stats["step_sessions"] += len(served)
        # one pass over the weights for the whole batch — the fused win
        cost = self._cost(m.flops(1) * len(served),
                          self._weight_bytes() + kv_read)
        return out[:len(live)], served, cost

    def _step_unfused(self, sessions: List[Any], x: np.ndarray,
                      ) -> Tuple[np.ndarray, List[Any], float]:
        m = self.module
        served: List[Any] = []
        outs: List[np.ndarray] = []
        cost = 0.0
        for i, sid in enumerate(sessions):
            st = self.by_session.get(sid)
            if st is None:
                continue
            st.last_used = self.sim.now
            xi = jnp.asarray(x[i])[None]          # (1,) tokens or (1, D)
            if m.is_first and xi.dtype == jnp.int32:
                xi = m.embed(xi[:, None])
            else:
                xi = xi[:, None]                  # (1, 1, D)
            cur = int(st.cache["len"])
            self._ensure_capacity(st, cur + 1)
            out, st.cache = self._apply(
                xi, self._positions(cur, 1, 1), st.cache)
            if m.is_last:
                out = m.head(out)[:, 0]           # (1, vocab)
            else:
                out = out[:, 0]                   # (1, d_model)
            outs.append(np.asarray(out[0]))
            served.append(sid)
            # every session re-reads the shard weights: M passes per step
            cost += self._cost(m.flops(1),
                               self._weight_bytes() + self._slot_kv_bytes(st))
        self.stats["step_sessions"] += len(served)
        out_arr = (np.stack(outs) if outs
                   else np.zeros((0, 1), dtype=np.float32))
        return out_arr, served, cost

    def slot_of(self, session: Any) -> Optional[int]:
        st = self.by_session.get(session)
        return None if st is None else st.slot
