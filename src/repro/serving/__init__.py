from .engine import GenerationEngine
from .sharded import ShardClient, ShardServer, plan_shards, deploy_sharded

__all__ = ["GenerationEngine", "ShardClient", "ShardServer", "plan_shards",
           "deploy_sharded"]
