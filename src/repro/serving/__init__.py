from .engine import GenerationEngine
from .batch import BatchEngine
from .router import LoadAwareRouter, hedged_call
from .pressure import PressureMonitor, load_publisher, publish_serving_plan
from .sharded import (ShardClient, ShardServer, plan_shards, deploy_sharded,
                      serve_fleet)

__all__ = ["GenerationEngine", "BatchEngine", "LoadAwareRouter",
           "hedged_call", "PressureMonitor", "load_publisher",
           "publish_serving_plan", "ShardClient", "ShardServer",
           "plan_shards", "deploy_sharded", "serve_fleet"]
