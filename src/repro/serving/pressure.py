"""Replica pressure: serving load feeding back into content replication.

Shard servers publish queue depth and slot occupancy as LWW registers in
the ``serving/<fleet>`` CRDT namespace (delta-pushed on the ``crdt/serving``
topic — PR 5's watch/push plane), alongside a *serving plan* register that
records the layer split and the root CID of each shard's param sub-DAG
(published per shard at deploy time via the delta-friendly checkpoint
path).

A :class:`PressureMonitor` runs on idle peers: it watches the fleet's
load registers, and when a shard stays hot for ``sustain`` consecutive
observations — aggregate (busy slots + queued admissions) / capacity at
or above ``hot_occupancy`` — and the shard has fewer than ``max_replicas``
live replicas, the monitor swarm-fetches that shard's param sub-DAG from
the content plane, constructs a local :class:`ShardServer`, and registers
itself as a new DHT provider of ``shard/<fleet>/<i>``.  Routing pressure
thereby *creates* replicas, the first path in the repo where the serving
plane drives content-plane replication instead of the other way round.

Crash semantics are passive: a dead server simply stops refreshing its
load register, so its samples go stale (``stale_after``) and drop out of
the pressure estimate — no failure detector needed.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Optional, Tuple

import numpy as np

from repro.checkpoint.lattica_ckpt import fetch_checkpoint, publish_checkpoint
from repro.core.cid import CID
from repro.core.node import LatticaNode
from repro.models.config import ModelConfig

__all__ = ["load_key", "plan_key", "replicas_key", "tree_from_flat",
           "publish_serving_plan", "read_serving_plan", "load_publisher",
           "PressureMonitor"]


def load_key(fleet: str, shard_idx: int, host: str) -> str:
    return f"serving/{fleet}/load/{shard_idx}/{host}"


def plan_key(fleet: str) -> str:
    return f"serving/{fleet}/plan"


def replicas_key(fleet: str, shard_idx: int) -> str:
    return f"serving/{fleet}/replicas/{shard_idx}"


def _shard_ckpt_fleet(fleet: str, shard_idx: int) -> str:
    """Checkpoint-registry namespace for one shard's param sub-DAG."""
    return f"{fleet}-shard{shard_idx}"


def tree_from_flat(flat: Dict[str, np.ndarray]) -> Any:
    """Rebuild a nested params pytree from ``{path: leaf}`` with
    ``/``-joined paths (the ``params_to_parts`` naming).  Levels whose keys
    are all decimal integers become lists — which is how list-of-dicts
    block stacks (the ssm arch) flatten."""
    root: Dict[str, Any] = {}
    for path, leaf in flat.items():
        node = root
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf

    def collapse(d: Any) -> Any:
        if not isinstance(d, dict):
            return d
        out = {k: collapse(v) for k, v in d.items()}
        if out and all(k.isdigit() for k in out):
            return [out[k] for k in sorted(out, key=int)]
        return out

    return collapse(root)


# ---------------------------------------------------------------- plan plane
def publish_serving_plan(node: LatticaNode, fleet: str,
                         plan: List[Tuple[int, int]],
                         parts: List[Dict[str, Any]]) -> Generator:
    """Publish every shard's param subset as its own checkpoint DAG and
    record the serving plan (layer ranges + per-shard root CIDs) in the
    fleet's CRDT namespace.  Returns the per-shard root CIDs."""
    roots: List[CID] = []
    for i, sub in enumerate(parts):
        root = yield from publish_checkpoint(
            node, sub, step=0, fleet=_shard_ckpt_fleet(fleet, i))
        roots.append(root)
    value = (len(plan),
             tuple((lo, hi) for lo, hi in plan),
             tuple((r.codec, r.digest) for r in roots))
    node.store.register(plan_key(fleet)).set(
        value, node.sim.now, node.host.name)
    return roots


def read_serving_plan(node: LatticaNode, fleet: str,
                      ) -> Optional[Tuple[int, List[Tuple[int, int]],
                                          List[CID]]]:
    val = node.store.register(plan_key(fleet)).value()
    if val is None:
        return None
    n_shards, plan, roots = val
    return (int(n_shards),
            [(int(lo), int(hi)) for lo, hi in plan],
            [CID(int(c), bytes(d)) for c, d in roots])


# ---------------------------------------------------------------- load plane
def load_publisher(server: Any, interval: float = 0.25,
                   refresh: float = 2.0) -> Generator:
    """Server-side loop: keep ``serving/<fleet>/load/<shard>/<host>`` fresh.

    Writes on occupancy change and at least every ``refresh`` seconds
    (the heartbeat that distinguishes *idle* from *dead*); stops when the
    server stops, which is exactly what lets monitors age the sample out.
    """
    node = server.node
    key = load_key(server.fleet, server.shard_idx, node.host.name)
    last: Optional[Tuple[int, int]] = None
    last_pub = -1e9
    node.store.orset(replicas_key(server.fleet, server.shard_idx)).add(
        node.host.name, node.host.name)
    while server.alive:
        eng = server.engine
        cur = (eng.slots_used, eng.queue_depth)
        now = node.sim.now
        if cur != last or now - last_pub >= refresh:
            node.store.register(key).set(
                (cur[0], cur[1], eng.n_slots, round(now, 3)),
                now, node.host.name)
            last, last_pub = cur, now
        yield interval
    return None


# ------------------------------------------------------------------ monitor
class PressureMonitor:
    """Idle-peer loop that turns sustained shard pressure into a replica."""

    def __init__(self, node: LatticaNode, cfg: ModelConfig, fleet: str,
                 hot_occupancy: float = 0.75, sustain: int = 3,
                 interval: float = 0.5, stale_after: float = 3.0,
                 max_replicas: int = 3, n_slots: int = 8,
                 page_size: int = 32,
                 cold_occupancy: float = 0.15, cold_sustain: int = 6):
        self.node = node
        self.cfg = cfg
        self.fleet = fleet
        self.hot_occupancy = hot_occupancy
        self.sustain = sustain
        self.interval = interval
        self.stale_after = stale_after
        self.max_replicas = max_replicas
        self.n_slots = n_slots
        self.page_size = page_size
        #: retirement thresholds: a shard whose aggregate occupancy stays
        #: below ``cold_occupancy`` for ``cold_sustain`` consecutive
        #: observations gets its monitor-spawned replica retired (once
        #: drained) — pressure creates replicas AND takes them back
        self.cold_occupancy = cold_occupancy
        self.cold_sustain = cold_sustain
        self.running = True
        self.spawned: List[Any] = []
        self._spawned_shards: set = set()
        self._streak: Dict[int, int] = {}
        self._cold_streak: Dict[int, int] = {}
        self.stats = {"observations": 0, "hot_observations": 0, "spawned": 0,
                      "fetch_failures": 0, "retired": 0}
        node.join_crdt_push("serving")

    def stop(self) -> None:
        self.running = False

    # -- pressure estimate ---------------------------------------------------
    def shard_pressure(self) -> Dict[int, float]:
        """Per-shard (busy slots + queued) / capacity over fresh samples."""
        prefix = f"serving/{self.fleet}/load/"
        now = self.node.sim.now
        agg: Dict[int, List[Tuple[int, int, int]]] = {}
        for key in list(self.node.store.entries):
            if not key.startswith(prefix):
                continue
            val = self.node.store.register(key).value()
            if val is None:
                continue
            used, queued, n_slots, ts = val
            if now - float(ts) > self.stale_after:
                continue        # dead or partitioned replica: age it out
            shard = int(key[len(prefix):].split("/", 1)[0])
            agg.setdefault(shard, []).append(
                (int(used), int(queued), int(n_slots)))
        out: Dict[int, float] = {}
        for shard, samples in agg.items():
            cap = sum(s[2] for s in samples)
            demand = sum(s[0] + s[1] for s in samples)
            out[shard] = demand / cap if cap else 0.0
        return out

    def replica_count(self, shard_idx: int) -> int:
        return len(self.node.store.orset(
            replicas_key(self.fleet, shard_idx)).value())

    # -- main loop -----------------------------------------------------------
    def run(self) -> Generator:
        while self.running:
            yield self.interval
            self.stats["observations"] += 1
            pressure = self.shard_pressure()
            for shard, p in pressure.items():
                if p >= self.hot_occupancy:
                    self.stats["hot_observations"] += 1
                    self._streak[shard] = self._streak.get(shard, 0) + 1
                else:
                    self._streak[shard] = 0
                if (self._streak.get(shard, 0) >= self.sustain
                        and shard not in self._spawned_shards
                        and self.replica_count(shard) < self.max_replicas):
                    yield from self.spawn_replica(shard)
            # -- retirement: sustained cold + drained → scale back down
            for server in list(self.spawned):
                shard = server.shard_idx
                if pressure.get(shard, 0.0) <= self.cold_occupancy:
                    self._cold_streak[shard] = \
                        self._cold_streak.get(shard, 0) + 1
                else:
                    self._cold_streak[shard] = 0
                eng = server.engine
                if (self._cold_streak.get(shard, 0) >= self.cold_sustain
                        and eng.slots_used == 0 and eng.queue_depth == 0):
                    yield from self.retire_replica(server)
        return None

    def retire_replica(self, server: Any) -> Generator:
        """Gracefully take a monitor-spawned replica back out of service:
        withdraw the DHT provider record, leave the replica ORSet, release
        the pinned shard params.  The load register is *not* touched — the
        stopped publisher loop lets it age out, the same passive path that
        covers crashes.  The shard stays eligible for a future respawn."""
        shard = server.shard_idx
        server.alive = False              # drained by precondition: no waiters
        yield from server.unannounce()
        self.node.store.orset(replicas_key(self.fleet, shard)).remove(
            self.node.host.name)
        self.node.unpin_latest(f"ckpt/{_shard_ckpt_fleet(self.fleet, shard)}")
        self.spawned.remove(server)
        self._spawned_shards.discard(shard)
        self._cold_streak[shard] = 0
        self.stats["retired"] += 1
        return None

    def _pull_plane(self) -> Generator:
        """One-shot anti-entropy with a few known peers: a monitor that
        joined after the plan was published (push only carries *new*
        mutations) reconciles the serving namespace off the mesh."""
        peers = sorted(self.node.peers, key=lambda p: p.digest)
        self.node.sim.rng.shuffle(peers)
        for pid in peers[:3]:
            try:
                yield from self.node.sync_crdt_with(self.node.peers[pid])
            except Exception:   # noqa: BLE001 — peer down; try the next
                continue
            if self.node.store.register(
                    plan_key(self.fleet)).value() is not None:
                return
        return None

    def spawn_replica(self, shard_idx: int) -> Optional[Any]:
        """Fetch the shard's param sub-DAG and start serving it."""
        from .sharded import ShardModule, ShardServer

        plan = read_serving_plan(self.node, self.fleet)
        if plan is None:
            yield from self._pull_plane()
            plan = read_serving_plan(self.node, self.fleet)
        if plan is None:
            return None
        n_shards, ranges, roots = plan
        self._spawned_shards.add(shard_idx)   # one attempt per shard
        try:
            flat = yield from fetch_checkpoint(
                self.node, roots[shard_idx],
                fleet=_shard_ckpt_fleet(self.fleet, shard_idx))
        except Exception:       # noqa: BLE001 — swarm fetch failed; back off
            self.stats["fetch_failures"] += 1
            self._spawned_shards.discard(shard_idx)
            return None
        params = tree_from_flat(flat)
        module = ShardModule(self.cfg, params, ranges[shard_idx],
                             is_first=(shard_idx == 0),
                             is_last=(shard_idx == n_shards - 1))
        server = ShardServer(self.node, self.cfg, self.fleet, shard_idx,
                             module, n_slots=self.n_slots,
                             page_size=self.page_size)
        yield from server.announce()
        self.node.sim.process(load_publisher(server), daemon=True)
        self.spawned.append(server)
        self.stats["spawned"] += 1
        return server
