"""Mixture-of-Experts layer: top-k token-choice routing with capacity.

Classic dispatch/combine formulation (Shazeer et al.): tokens pick their
top-k experts, each expert processes at most C = ceil(k·T/E·cf) tokens,
overflow is dropped (residual passes through).  The dispatch is expressed as
scatter/gather so the expert dimension shards cleanly on the "model" mesh
axis (expert parallelism) — the pattern the paper's content/RPC substrate is
built to feed.

Router gating (softmax → top-k → renormalize) has a Pallas kernel in
``repro.kernels.moe_gating``; the jnp path below doubles as its oracle.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, init_mlp, run_mlp
from .config import ModelConfig

Params = Dict[str, Any]


def _constrain_groups(x: jax.Array, cfg: ModelConfig, dim: int = 0,
                      model_dim: Optional[int] = None) -> jax.Array:
    """Pin dim ``dim`` of a dispatch buffer to the data axes: dim=0 (G) is
    the token-group layout, dim=1 (E) is the expert-parallel layout; a
    constraint flip between them lowers to one all-to-all.  ``model_dim``
    additionally keeps that dim sharded on the TP axis (so the F-contracted
    down-projection reduce-scatters instead of all-reducing to full D)."""
    if cfg.moe_groups <= 1 or not cfg.act_batch_axes:
        return x
    from jax.sharding import PartitionSpec as P

    axes: Any = (cfg.act_batch_axes if len(cfg.act_batch_axes) > 1
                 else cfg.act_batch_axes[0])
    spec: list = [None] * x.ndim
    spec[dim] = axes
    if model_dim is not None and cfg.act_model_axis:
        if x.shape[model_dim] % 16 == 0:
            spec[model_dim] = cfg.act_model_axis
    return jax.lax.with_sharding_constraint(x, P(*spec))


def init_moe(cfg: ModelConfig, key: jax.Array, dtype: Any) -> Params:
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_exp
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], (D, E), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (E, D, F), dtype),
        "w_up": dense_init(ks[2], (E, D, F), dtype),
        "w_down": dense_init(ks[3], (E, F, D), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], D, cfg.n_shared_experts * F, dtype)
    return p


def topk_gating(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Softmax over experts, keep top-k, renormalize.

    logits: (T, E) float32.  Returns (weights (T,k), experts (T,k), probs (T,E)).
    This is the reference implementation; ``repro.kernels.moe_gating``
    provides the fused TPU kernel.
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    return weights, experts, probs


def run_moe(p: Params, cfg: ModelConfig, x: jax.Array,
            use_kernel: bool = False, no_drop: bool = False,
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (y, aux_loss).

    ``no_drop=True`` (decode/serving): per-expert capacity covers the worst
    case so no token is ever dropped mid-generation.  Training keeps the
    capacity-factor drop semantics (the aux loss pushes the router toward
    balance).
    """
    B, S, D = x.shape
    E, K, F = cfg.n_experts, cfg.moe_top_k, cfg.d_exp
    T = B * S
    xt = x.reshape(T, D)
    logits = xt.astype(jnp.float32) @ p["router"]
    if use_kernel:
        from repro.kernels.ops import moe_gating
        weights, experts, probs = moe_gating(logits, K)
    else:
        weights, experts, probs = topk_gating(logits, K)

    # token groups: at scale G = number of data shards, so each group's
    # dispatch buffer stays local and experts see a (G, E, C, D) batch that
    # shards group-dim on data and expert/ffn dims on model (all-to-all
    # traffic emerges from the G×E resharding — the MoE pattern the paper's
    # substrate is built to carry across clusters)
    G = cfg.moe_groups if cfg.moe_groups > 1 and T % cfg.moe_groups == 0 else 1
    Tg = T // G
    if no_drop:
        # serving: cover the worst case exactly for small token counts
        # (decode), and a 2x load-imbalance margin for large ones (prefill) —
        # capacity = Tg at 1M prefill tokens would be a terabyte-scale buffer
        if Tg <= 512:
            capacity = Tg
        else:
            capacity = min(int(2 * K * Tg / E) + 1, Tg)
    else:
        capacity = int(max(K * Tg * cfg.capacity_factor / E, K))
        capacity = min(capacity, Tg)

    xg = _constrain_groups(xt.reshape(G, Tg, D), cfg, dim=0)
    wg = _constrain_groups(weights.reshape(G, Tg, K), cfg, dim=0)
    eg = _constrain_groups(experts.reshape(G, Tg, K), cfg, dim=0)

    def dispatch_combine(xg1, wg1, eg1):
        """One group's scatter → expert buffers → gather."""
        flat_exp = eg1.reshape(-1)                          # (Tg*K,)
        onehot = jax.nn.one_hot(flat_exp, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) * onehot - 1
        pos_in_exp = jnp.take_along_axis(pos, flat_exp[:, None], axis=1)[:, 0]
        keep = pos_in_exp < capacity
        slot = flat_exp * capacity + jnp.where(keep, pos_in_exp, 0)
        flat_w = wg1.reshape(-1) * keep
        token_idx = jnp.repeat(jnp.arange(Tg), K)
        buf = jnp.zeros((E * capacity, D), x.dtype)
        contrib = jnp.where(keep[:, None], xg1[token_idx], 0)
        buf = buf.at[slot].add(contrib)
        return buf.reshape(E, capacity, D), (slot, flat_w, keep, token_idx)

    eb, combine_info = jax.vmap(dispatch_combine)(xg, wg, eg)  # (G,E,C,D)
    # expert-parallel layout when E divides the group count (dbrx: 16/16):
    # dispatch buffers flip from G-sharded to E-sharded — ONE explicit
    # all-to-all instead of XLA's fallback gather of the whole buffer —
    # compute runs where the expert weights live, then flip back
    ep_layout = G > 1 and E % G == 0
    eb = _constrain_groups(eb, cfg, dim=0)   # scatter completes G-local...
    if ep_layout:
        eb = _constrain_groups(eb, cfg, dim=1)   # ...then ONE relayout to E

    # expert FFN (batched over experts — shards on expert/model axes)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", eb, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", eb, p["w_up"])
    eo = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    eo = _constrain_groups(eo, cfg, dim=0)

    def combine(eo1, info):
        slot, flat_w, keep, token_idx = info
        flat = eo1.reshape(E * capacity, D)
        gathered = flat[slot] * flat_w[:, None].astype(x.dtype)
        return jnp.zeros((Tg, D), x.dtype).at[token_idx].add(
            jnp.where(keep[:, None], gathered, 0))

    y = jax.vmap(combine)(eo, combine_info).reshape(T, D)

    if "shared" in p:
        y = y + run_mlp(p["shared"], xt)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(experts[:, 0], E, dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * E * cfg.router_aux_weight
    return y.reshape(B, S, D), aux
