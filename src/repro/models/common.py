"""Shared layers: norms, rotary embeddings (incl. M-RoPE), attention, MLP.

Everything is a pure function over explicit param pytrees — no framework
dependency — so the same code path serves training, prefill, decode, and
the multi-device dry-run under pjit.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = Dict[str, Any]

#: below this sequence length, plain S² attention is cheaper than streaming
FLASH_MIN_SEQ = 2048


def constrain_batch_seq(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Pin (batch, seq, ...) activations to (batch axes, seq axis, ...) —
    the layout recurrent stacks keep end-to-end under sequence parallelism."""
    if not cfg.act_seq_axis:
        return constrain_batch(x, cfg)
    from jax.sharding import PartitionSpec as P

    b: Any = None
    if cfg.act_batch_axes:
        b = (cfg.act_batch_axes if len(cfg.act_batch_axes) > 1
             else cfg.act_batch_axes[0])
    spec = [b, cfg.act_seq_axis] + [None] * (x.ndim - 2)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_batch(x: jax.Array, cfg: ModelConfig, dim: int = 0) -> jax.Array:
    """Pin dim ``dim`` of an activation to the batch mesh axes (no-op when
    cfg.act_batch_axes is unset — single-device tests/examples)."""
    if not cfg.act_batch_axes:
        return x
    from jax.sharding import PartitionSpec as P

    axes: Any = (cfg.act_batch_axes if len(cfg.act_batch_axes) > 1
                 else cfg.act_batch_axes[0])
    spec = [None] * x.ndim
    spec[dim] = axes
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ----------------------------------------------------------------- init utils

def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype: Any,
               scale: Optional[float] = None) -> jax.Array:
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ----------------------------------------------------------------------- norms

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ------------------------------------------------------------------------ RoPE

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the head dim is split into (t, h, w)
    sections, each rotated by its own position stream.

    x: (B, S, H, hd); positions3: (3, B, S) int32 — temporal, height, width.
    ``sections`` counts *frequency pairs* per stream (sum == hd // 2).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # select which position stream drives each frequency pair
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections),
                         total_repeat_length=hd // 2)   # (hd/2,)
    pos = positions3.astype(jnp.float32)                # (3,B,S)
    pos_per_freq = pos[sec_ids]                         # (hd/2, B, S)
    angles = jnp.moveaxis(pos_per_freq, 0, -1) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- attention

def init_attention(cfg: ModelConfig, key: jax.Array, dtype: Any) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.q_dim), dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.kv_dim), dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.kv_dim), dtype),
        "wo": dense_init(ks[3], (cfg.q_dim, cfg.d_model), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), dtype)
        p["k_norm"] = jnp.ones((cfg.hd,), dtype)
    return p


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.repeat(x, n_rep, axis=2)


def attention_scores(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: Optional[jax.Array]) -> jax.Array:
    """Reference attention: q (B,S,H,hd), k/v (B,T,H,hd), mask (S,T) or
    (B,1,S,T) additive."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask is not None:
        logits = logits + mask
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def causal_mask(s: int, t: int, window: int = 0,
                offset: int = 0) -> jax.Array:
    """Additive mask (1,1,S,T).  ``offset`` = number of cached tokens before
    the current block (so query i attends keys <= offset+i).  ``window`` > 0
    limits attention to the trailing ``window`` keys (sliding window)."""
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(t)[None, :]
    ok = kj <= qi
    if window > 0:
        ok &= kj > (qi - window)
    return jnp.where(ok, 0.0, -1e9).astype(jnp.float32)[None, None]


def run_attention(p: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array,
                  kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  cache_len: Optional[jax.Array] = None,
                  mask: Optional[jax.Array] = None) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """GQA attention.  Without a cache: causal self-attention over x.
    With a cache (k,v of shape (B,T,Hk,hd)): append at ``cache_len`` and
    attend over the cache (decode / incremental prefill).

    positions: (B,S) or (3,B,S) when cfg.mrope.
    """
    B, S, _ = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Hk, hd)
    v = (x @ p["wv"]).reshape(B, S, Hk, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache                                  # (B,T,Hk,hd)
        T = ck.shape[1]
        ring = cfg.window > 0 and T == cfg.window
        qpos = cache_len + jnp.arange(S)                   # (S,) query positions
        if ring:
            # ring-buffer sliding window cache (mod-scatter handles wrap)
            if S >= T:
                idx = (cache_len + S - T + jnp.arange(T)) % T
                ck = ck.at[:, idx].set(k[:, -T:])
                cv = cv.at[:, idx].set(v[:, -T:])
            else:
                idx = (cache_len + jnp.arange(S)) % T
                ck = ck.at[:, idx].set(k)
                cv = cv.at[:, idx].set(v)
            kpos = _ring_pos(jnp.arange(T), cache_len + S, T)   # (T,)
        else:
            ck = jax.lax.dynamic_update_slice(ck, k, (0, cache_len, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v, (0, cache_len, 0, 0))
            kpos = jnp.arange(T)
        ok = (kpos[None, :] >= 0) & (kpos[None, :] <= qpos[:, None])
        if cfg.window > 0:
            ok &= kpos[None, :] > (qpos[:, None] - cfg.window)
        new_cache = (ck, cv)
        if S > 1 and S >= FLASH_MIN_SEQ:
            # initial prefill (cache starts empty): stream the NEW block's
            # k/v flash-style — O(S·blk) memory instead of O(S·T)
            from .chunked import flash_attention_jnp
            kk = _repeat_kv(k, H // Hk)
            vv = _repeat_kv(v, H // Hk)
            out = flash_attention_jnp(q, kk, vv, True, cfg.window)
        else:
            ok = (kpos[None, :] >= 0) & (kpos[None, :] <= qpos[:, None])
            if cfg.window > 0:
                ok &= kpos[None, :] > (qpos[:, None] - cfg.window)
            amask = jnp.where(ok, 0.0, -1e9).astype(jnp.float32)[None, None]
            kk = _repeat_kv(ck, H // Hk)
            vv = _repeat_kv(cv, H // Hk)
            out = attention_scores(q, kk, vv, amask)
    else:
        kk = _repeat_kv(k, H // Hk)
        vv = _repeat_kv(v, H // Hk)
        if cfg.use_flash_kernel and not cfg.mrope and mask is None:
            from repro.kernels.ops import flash_attention
            out = flash_attention(q, kk, vv, causal=True, window=cfg.window)
        elif mask is None and S >= FLASH_MIN_SEQ:
            from .chunked import flash_attention_jnp
            out = flash_attention_jnp(q, kk, vv, True, cfg.window)
        else:
            if mask is None:
                mask = causal_mask(S, S, cfg.window)
            out = attention_scores(q, kk, vv, mask)
    y = out.reshape(B, S, H * hd) @ p["wo"]
    return y, new_cache


def _ring_pos(slot: jax.Array, length: jax.Array, T: int) -> jax.Array:
    """Absolute position stored in ring slot ``slot`` when ``length`` tokens
    have been written into a ring of size T."""
    # last written slot is (length-1) % T holding position length-1
    last_slot = (length - 1) % T
    delta = (last_slot - slot) % T
    return (length - 1) - delta


# ------------------------------------------------------------------------- MLP

def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype: Any) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def run_mlp(p: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
