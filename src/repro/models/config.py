"""Model configuration shared by every assigned architecture."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0            # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- M-RoPE (Qwen2-VL) ---
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0            # per-expert FFN width (0 => d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_groups: int = 1          # token-dispatch groups (= data shards at scale)

    # --- SSM / hybrid ---
    ssm_state: int = 0           # mamba state size N
    d_inner: int = 0             # mamba inner width (0 => 2*d_model)
    slstm_every: int = 0         # xLSTM: every k-th block is sLSTM (0 = none)

    # --- encoder-decoder (audio) ---
    enc_layers: int = 0
    enc_seq: int = 0             # encoder source length (precomputed frames)
    d_source: int = 0            # frontend embedding dim (stub input)

    # --- VLM ---
    n_patches: int = 0           # patch embeddings per image (stub input)

    # --- attention variant ---
    window: int = 0              # 0 = full causal; >0 = sliding window

    # runtime knobs (not architecture)
    remat: bool = False          # activation checkpoint each block
    use_flash_kernel: bool = False
    #: mesh axes carrying the batch dim of activations; when set (under
    #: pjit with a mesh context) block-boundary activations are pinned to
    #: P(act_batch_axes, None, ...) so sharding propagation can't flip to
    #: replicated-batch layouts
    act_batch_axes: Tuple[str, ...] = ()
    #: sequence parallelism for recurrent (mLSTM) prefill: split the
    #: sequence into this many segments, run them in parallel over
    #: ``act_seq_axis``, and stitch with an associative state scan
    seq_segments: int = 0
    act_seq_axis: str = ""
    #: tensor-parallel mesh axis name (for keeping contracted-dim outputs
    #: sharded instead of all-reduced to full, e.g. MoE down-projection)
    act_model_axis: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def d_exp(self) -> int:
        return self.d_expert or self.d_ff

    @property
    def d_in(self) -> int:
        return self.d_inner or 2 * self.d_model

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                vocab: int = 512, **kw) -> "ModelConfig":
        """Smoke-test variant of the same family (CPU-friendly)."""
        scale = d_model / self.d_model
        n_heads = max(2, min(self.n_heads, 4))
        ratio = max(1, self.n_heads // max(self.n_kv_heads, 1))
        n_kv = max(1, n_heads // min(ratio, n_heads))
        updates = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=max(64, int(self.d_ff * scale) // 16 * 16) if self.d_ff else 0,
            vocab=vocab,
            enc_layers=min(self.enc_layers, 2),
            enc_seq=min(self.enc_seq, 64),
            n_patches=min(self.n_patches, 16),
            n_experts=min(self.n_experts, 4),
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_top_k=min(self.moe_top_k, 2),
            d_expert=max(32, int(self.d_exp * scale) // 8 * 8) if self.n_experts else 0,
            capacity_factor=8.0 if self.n_experts else self.capacity_factor,
            d_inner=2 * d_model if self.d_inner else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            window=min(self.window, 64) if self.window else 0,
            mrope_sections=tuple(
                s * (d_model // n_heads) // self.hd for s in self.mrope_sections),
        )
        updates.update(kw)
        return replace(self, **updates)

    def param_count(self) -> int:
        """Approximate parameter count N (for 6·N·D roofline math)."""
        D, L, V = self.d_model, self.n_layers, self.vocab
        attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
        if self.arch == "ssm":
            # mLSTM block: qkv projections + gates + out + ff
            blk = 4 * D * self.hd * self.n_heads + 2 * D
        else:
            blk = attn
        if self.n_experts:
            moe = self.n_experts * 3 * D * self.d_exp + D * self.n_experts
            moe += self.n_shared_experts * 3 * D * self.d_exp
            blk += moe
        elif self.d_ff:
            blk += 3 * D * self.d_ff
        if self.arch in ("hybrid",):
            d_in = self.d_in
            blk += 2 * D * d_in + d_in * (2 * self.ssm_state + 2) + d_in * D
        total = L * blk + V * D * (1 if self.tie_embeddings else 2) + D
        if self.enc_layers:
            total += self.enc_layers * (attn + 3 * D * self.d_ff)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-to experts count)."""
        if not self.n_experts:
            return self.param_count()
        D, L = self.d_model, self.n_layers
        full = self.param_count()
        all_expert = L * self.n_experts * 3 * D * self.d_exp
        active_expert = L * self.moe_top_k * 3 * D * self.d_exp
        return int(full - all_expert + active_expert)
