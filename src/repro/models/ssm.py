"""Recurrent sequence layers: Mamba (selective SSM), mLSTM and sLSTM (xLSTM).

Training/prefill use parallel formulations (associative scan for Mamba, the
stabilized quadratic D-matrix form for mLSTM); decode uses O(1)-per-token
recurrent state updates — this is what makes the ``long_500k`` shape feasible
for the ssm/hybrid architectures where dense attention would be quadratic.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import constrain_batch, constrain_batch_seq, dense_init, rms_norm
from .config import ModelConfig

Params = Dict[str, Any]

DT_RANK = 16
CONV_K = 4
MAMBA_CHUNK = 128     # chunkwise-scan block (memory/recompute trade-off)
MLSTM_CHUNK = 256     # mLSTM chunkwise-parallel block


# =====================================================================
# Mamba-style selective SSM head (Hymba's parallel-SSM branch)
# =====================================================================

def init_mamba(cfg: ModelConfig, key: jax.Array, dtype: Any) -> Params:
    D, d_in, N = cfg.d_model, cfg.d_in, cfg.ssm_state
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], (D, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (CONV_K, d_in), dtype, scale=0.5),
        "w_bc": dense_init(ks[2], (d_in, 2 * N), dtype),
        "w_dt1": dense_init(ks[3], (d_in, DT_RANK), dtype),
        "w_dt2": dense_init(ks[4], (DT_RANK, d_in), dtype),
        "dt_bias": jnp.zeros((d_in,), dtype),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (d_in, 1))),
        "D_skip": jnp.ones((d_in,), jnp.float32),
        "w_out": dense_init(ks[5], (d_in, D), dtype),
    }


def _causal_depthwise_conv(u: jax.Array, w: jax.Array,
                           tail: Optional[jax.Array] = None) -> jax.Array:
    """u: (B,S,C), w: (K,C).  ``tail``: (B,K-1,C) of preceding context."""
    B, S, C = u.shape
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, K - 1, C), u.dtype)
    up = jnp.concatenate([tail, u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(K):
        out = out + up[:, i:i + S, :] * w[i]
    return out


def mamba_scan(dA: jax.Array, dBu: jax.Array,
               h0: Optional[jax.Array] = None) -> jax.Array:
    """Associative scan of h_t = dA_t * h_{t-1} + dBu_t along axis 1.

    dA, dBu: (B, S, d_in, N).  Returns all h_t (B,S,d_in,N).
    """
    if h0 is not None:
        dBu = dBu.at[:, 0].add(dA[:, 0] * h0)

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    return h


def run_mamba(p: Params, cfg: ModelConfig, x: jax.Array,
              state: Optional[Tuple[jax.Array, jax.Array]] = None,
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """x: (B,S,D).  state = (h (B,d_in,N), conv_tail (B,K-1,d_in)) for decode.

    Returns (y (B,S,D), new_state or None).
    """
    B, S, D = x.shape
    d_in, N = cfg.d_in, cfg.ssm_state
    uz = x @ p["w_in"]
    u, z = jnp.split(uz, 2, axis=-1)
    conv_tail = state[1] if state is not None else None
    u_conv = _causal_depthwise_conv(u, p["conv_w"], conv_tail)
    new_tail = jnp.concatenate(
        [conv_tail if conv_tail is not None
         else jnp.zeros((B, CONV_K - 1, d_in), u.dtype), u],
        axis=1)[:, -(CONV_K - 1):, :]
    u = jax.nn.silu(u_conv)

    dt = jax.nn.softplus(
        (u @ p["w_dt1"]) @ p["w_dt2"] + p["dt_bias"]).astype(jnp.float32)
    bc = u @ p["w_bc"]
    B_, C_ = jnp.split(bc.astype(jnp.float32), 2, axis=-1)     # (B,S,N)
    A = -jnp.exp(p["A_log"])                                   # (d_in,N)
    uf = u.astype(jnp.float32)
    h0 = state[0] if state is not None else None

    if S == 1 and state is not None:
        dA = jnp.exp(dt[:, 0, :, None] * A)                    # O(1) decode
        dBu = (dt[:, 0] * uf[:, 0])[..., None] * B_[:, 0, None, :]
        h1 = dA * h0 + dBu
        y = jnp.einsum("bdn,bn->bd", h1, C_[:, 0])[:, None]
        h_last = h1
    else:
        # chunkwise scan: (dA, dBu) and h live only per chunk (remat'd),
        # so the (B,S,d_in,N) tensor is never materialized
        W = MAMBA_CHUNK if S % MAMBA_CHUNK == 0 else S
        nC = S // W
        if h0 is None:
            h0 = jnp.zeros((B, d_in, N), jnp.float32)

        def chunk(h0c, blk):
            dA = jnp.exp(blk["dt"][..., None] * A)             # (B,W,d,N)
            dBu = (blk["dt"] * blk["u"])[..., None] * blk["B"][:, :, None, :]
            h = mamba_scan(dA, dBu, h0c)
            yc = jnp.einsum("bsdn,bsn->bsd", h, blk["C"])
            return h[:, -1], yc

        xs = {
            "dt": dt.reshape(B, nC, W, d_in).swapaxes(0, 1),
            "u": uf.reshape(B, nC, W, d_in).swapaxes(0, 1),
            "B": B_.reshape(B, nC, W, N).swapaxes(0, 1),
            "C": C_.reshape(B, nC, W, N).swapaxes(0, 1),
        }
        h_last, ys = jax.lax.scan(jax.checkpoint(chunk), h0, xs)
        y = ys.swapaxes(0, 1).reshape(B, S, d_in)
    y = y + p["D_skip"] * uf
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    new_state = (h_last, new_tail) if state is not None else None
    return y, new_state


# =====================================================================
# mLSTM (xLSTM): matrix memory with exponential gating
# =====================================================================

def init_mlstm(cfg: ModelConfig, key: jax.Array, dtype: Any) -> Params:
    D = cfg.d_model
    d_in = 2 * D                      # xLSTM pre-up-projection factor 2
    H = cfg.n_heads
    hd = d_in // H
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (D, 2 * d_in), dtype),       # x and gate
        "wq": dense_init(ks[1], (d_in, d_in), dtype),
        "wk": dense_init(ks[2], (d_in, d_in), dtype),
        "wv": dense_init(ks[3], (d_in, d_in), dtype),
        "w_i": dense_init(ks[4], (d_in, H), jnp.float32, scale=0.02),
        "b_i": jnp.zeros((H,), jnp.float32),
        "w_f": dense_init(ks[5], (d_in, H), jnp.float32, scale=0.02),
        "b_f": jnp.full((H,), 3.0, jnp.float32),   # forget-gate bias init
        "norm": jnp.ones((d_in,), dtype),
        "w_down": dense_init(ks[6], (d_in, D), dtype),
    }


def run_mlstm(p: Params, cfg: ModelConfig, x: jax.Array,
              state: Optional[Tuple[jax.Array, ...]] = None,
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, ...]]]:
    """x: (B,S,D).  state = (C (B,H,hd,hd), n (B,H,hd), m (B,H)) for decode."""
    B, S, D = x.shape
    H = cfg.n_heads
    seq_par = (cfg.seq_segments > 1 and S > 1
               and S % (cfg.seq_segments * MLSTM_CHUNK) == 0)
    up = x @ p["w_up"]
    if seq_par:
        up = constrain_batch_seq(up, cfg)
    xin, z = jnp.split(up, 2, axis=-1)                         # (B,S,d_in)
    d_in = xin.shape[-1]
    hd = d_in // H
    q = (xin @ p["wq"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (xin @ p["wk"]).reshape(B, S, H, hd).astype(jnp.float32) / math.sqrt(hd)
    v = (xin @ p["wv"]).reshape(B, S, H, hd).astype(jnp.float32)
    log_i = (xin.astype(jnp.float32) @ p["w_i"] + p["b_i"])    # (B,S,H)
    log_f = jax.nn.log_sigmoid(xin.astype(jnp.float32) @ p["w_f"] + p["b_f"])

    if seq_par:
        # sequence-parallel prefill: segments run concurrently across the
        # model axis; an associative scan over per-segment states stitches
        # causality back together (beyond-paper optimization, §Perf)
        h, total = _mlstm_seqpar(cfg, q, k, v, log_i, log_f, state)
        new_state = total if state is not None else None
    elif S == 1 and state is not None:
        C0, n0, m0 = state
        m1 = jnp.maximum(log_f[:, 0] + m0, log_i[:, 0])        # (B,H)
        i1 = jnp.exp(log_i[:, 0] - m1)
        f1 = jnp.exp(log_f[:, 0] + m0 - m1)
        C1 = f1[..., None, None] * C0 + \
            i1[..., None, None] * (k[:, 0][..., :, None] * v[:, 0][..., None, :])
        n1 = f1[..., None] * n0 + i1[..., None] * k[:, 0]
        num = jnp.einsum("bhij,bhi->bhj", C1, q[:, 0])
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhi,bhi->bh", n1, q[:, 0])), jnp.exp(-m1))
        h = (num / den[..., None]).reshape(B, 1, d_in)
        new_state = (C1, n1, m1)
    elif S <= MLSTM_CHUNK and state is None:
        # parallel (quadratic) stabilized D-matrix form — short sequences,
        # and the oracle the chunked path is tested against
        F = jnp.cumsum(log_f, axis=1)                          # (B,S,H)
        logD = (F[:, :, None, :] - F[:, None, :, :] +
                log_i[:, None, :, :])                          # (B,t,s,H)
        tri = jnp.tril(jnp.ones((S, S), bool))
        logD = jnp.where(tri[None, :, :, None], logD, -jnp.inf)
        m = jnp.max(logD, axis=2)                              # (B,t,H)
        Dm = jnp.exp(logD - m[:, :, None, :])                  # (B,t,s,H)
        scores = jnp.einsum("bthd,bshd->btsh", q, k) * Dm
        norm = jnp.maximum(jnp.abs(jnp.sum(scores, axis=2)), jnp.exp(-m))
        h = jnp.einsum("btsh,bshd->bthd", scores, v) / norm[..., None]
        h = h.reshape(B, S, d_in)
        new_state = None
    else:
        # chunkwise-parallel form: O(S·W) memory, carries (C, n, m) across
        # chunks — the same state the decode recurrence uses, so prefill
        # hands decode a ready state for free
        W = MLSTM_CHUNK if S % MLSTM_CHUNK == 0 else S
        nC = S // W
        if state is not None:
            C0, n0, m0 = (s.astype(jnp.float32) for s in state)
        else:
            C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
            n0 = jnp.zeros((B, H, hd), jnp.float32)
            m0 = jnp.full((B, H), -1e30, jnp.float32)

        # constrain=False: in-chunk layout constraints were only needed by
        # the (refuted) weight-replication serve experiment; under TP/FSDP
        # they fragment XLA fusion and inflate train memory (§Perf 4.1)
        chunk = _make_chunk_fn(cfg, W, constrain=False)
        xs = {
            "q": q.reshape(B, nC, W, H, hd).swapaxes(0, 1),
            "k": k.reshape(B, nC, W, H, hd).swapaxes(0, 1),
            "v": v.reshape(B, nC, W, H, hd).swapaxes(0, 1),
            "li": log_i.reshape(B, nC, W, H).swapaxes(0, 1),
            "lf": log_f.reshape(B, nC, W, H).swapaxes(0, 1),
        }
        (C1, n1, m1), hs = jax.lax.scan(jax.checkpoint(chunk), (C0, n0, m0), xs)
        h = hs.swapaxes(0, 1).reshape(B, S, d_in)
        new_state = (C1, n1, m1) if state is not None else None

    h = rms_norm(h.astype(x.dtype), p["norm"], cfg.norm_eps)
    y = (h * jax.nn.silu(z)) @ p["w_down"]
    return y, new_state


def _make_chunk_fn(cfg: ModelConfig, W: int, constrain: bool = True):
    """One mLSTM chunk step: intra-chunk quadratic D-form + inter via the
    carried (C,n,m) state + state update.  lax.scan body (remat'd).

    ``constrain=False`` under the seq-parallel vmap: per-element constraints
    would pin B but leave the mapped segment dim free (XLA then replicates
    it); the seq-par caller pins layouts outside the vmap instead."""

    def _c(t):
        return constrain_batch(t, cfg) if constrain else t

    def chunk(carry, blk):
        Cp, np_, mp = (_c(c) for c in carry)
        qc, kc, vc = (_c(blk[n]) for n in "qkv")
        li, lf = blk["li"], blk["lf"]                      # (B,W,H)
        F = jnp.cumsum(lf, axis=1)
        logD = (F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :])
        tri = jnp.tril(jnp.ones((W, W), bool))
        logD = jnp.where(tri[None, :, :, None], logD, -jnp.inf)
        m_intra = jnp.max(logD, axis=2)                    # (B,t,H)
        b_inter = F + mp[:, None, :]                       # (B,t,H)
        m_t = jnp.maximum(m_intra, b_inter)
        Dm = jnp.exp(logD - m_t[:, :, None, :])
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * Dm
        num = jnp.einsum("btsh,bshd->bthd", scores, vc)
        den = jnp.sum(scores, axis=2)                      # (B,t,H)
        w_int = jnp.exp(b_inter - m_t)                     # (B,t,H)
        num = num + w_int[..., None] * jnp.einsum("bthi,bhij->bthj", qc, Cp)
        den = den + w_int * jnp.einsum("bthi,bhi->bth", qc, np_)
        norm = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        hc = _c(num / norm[..., None])
        Cn, nn, m_next, _Ft = _chunk_state_update(Cp, np_, mp, kc, vc, li, lf)
        return (_c(Cn), _c(nn), _c(m_next)), hc

    return chunk


# ---------------------------------------------------------------------
# sequence-parallel mLSTM (beyond-paper §Perf optimization)
# ---------------------------------------------------------------------

def _constrain_seq(x: jax.Array, cfg: ModelConfig, dim: int = 0) -> jax.Array:
    """Pin a (G, B, ...) seq-parallel tensor: segment dim -> act_seq_axis
    AND batch dim -> act_batch_axes (leaving either free lets XLA replicate
    it — 126 GiB of all-gather in the first attempt)."""
    if not cfg.act_seq_axis:
        return x
    from jax.sharding import PartitionSpec as P

    spec: list = [None] * x.ndim
    spec[dim] = cfg.act_seq_axis
    if cfg.act_batch_axes and x.ndim > dim + 1:
        spec[dim + 1] = (cfg.act_batch_axes if len(cfg.act_batch_axes) > 1
                         else cfg.act_batch_axes[0])
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _chunk_state_update(Cp, np_, mp, kc, vc, li, lf):
    """One chunk's (C, n, m) update (shared by sequential & seq-par paths).
    kc/vc: (B,W,H,hd); li/lf: (B,W,H).  Returns (Cn, nn, m_next, Ftot)."""
    F = jnp.cumsum(lf, axis=1)
    Ft = F[:, -1]                                          # (B,H)
    m_next = jnp.maximum(mp + Ft, jnp.max(Ft[:, None] - F + li, axis=1))
    wk = jnp.exp(Ft[:, None] - F + li - m_next[:, None])   # (B,W,H)
    carry = jnp.exp(mp + Ft - m_next)
    Cn = (carry[..., None, None] * Cp
          + jnp.einsum("bsh,bshi,bshj->bhij", wk, kc, vc))
    nn = carry[..., None] * np_ + jnp.einsum("bsh,bshi->bhi", wk, kc)
    return Cn, nn, m_next, Ft


def _compose_states(sa, sb):
    """Associative composition: state after running segment b from state a.
    Each state = (C, n, m, Ftot) with the exp(-m) scaling convention."""
    Ca, na, ma, Fa = sa
    Cb, nb, mb, Fb = sb
    m = jnp.maximum(ma + Fb, mb)
    wa = jnp.exp(ma + Fb - m)
    wb = jnp.exp(mb - m)
    return (wa[..., None, None] * Ca + wb[..., None, None] * Cb,
            wa[..., None] * na + wb[..., None] * nb, m, Fa + Fb)


def _mlstm_seqpar(cfg: ModelConfig, q, k, v, log_i, log_f,
                  state: Optional[Tuple[jax.Array, ...]]):
    """Two-pass sequence-parallel chunked mLSTM.

    Pass 1 (parallel over segments): each segment's isolated end-state.
    Prefix: exclusive associative scan composing segment states (G steps of
    cheap (B,H,hd,hd) math — the ONLY cross-segment dependency).
    Pass 2 (parallel over segments): the normal chunk scan seeded with the
    segment's prefix state.

    q/k/v: (B,S,H,hd) (k pre-scaled); log_i/log_f: (B,S,H).
    Returns (h (B,S,d_in), total_state (C,n,m)).
    """
    B, S, H, hd = q.shape
    G = cfg.seq_segments
    W = MLSTM_CHUNK
    S_loc = S // G
    nC = S_loc // W

    def to_seg(x):
        # (B,S,...) -> (G,B,S_loc,...), segment dim pinned to the model axis
        x = x.reshape(B, G, S_loc, *x.shape[2:]).swapaxes(0, 1)
        return _constrain_seq(x, cfg, 0)

    qg, kg, vg = to_seg(q), to_seg(k), to_seg(v)
    lig, lfg = to_seg(log_i), to_seg(log_f)

    zeroC = jnp.zeros((B, H, hd, hd), jnp.float32)
    zeron = jnp.zeros((B, H, hd), jnp.float32)
    zerom = jnp.full((B, H), -1e30, jnp.float32)

    # ---- pass 1: isolated per-segment states --------------------------------
    def seg_state(k_s, v_s, li_s, lf_s):
        def upd(carry, blk):
            C, n, m, Fa = carry
            Cn, nn, mn, Ft = _chunk_state_update(
                C, n, m, blk["k"], blk["v"], blk["li"], blk["lf"])
            return (Cn, nn, mn, Fa + Ft), None

        xs = {
            "k": k_s.reshape(B, nC, W, H, hd).swapaxes(0, 1),
            "v": v_s.reshape(B, nC, W, H, hd).swapaxes(0, 1),
            "li": li_s.reshape(B, nC, W, H).swapaxes(0, 1),
            "lf": lf_s.reshape(B, nC, W, H).swapaxes(0, 1),
        }
        init = (zeroC, zeron, zerom, jnp.zeros((B, H), jnp.float32))
        (C, n, m, Fa), _ = jax.lax.scan(jax.checkpoint(upd), init, xs)
        return C, n, m, Fa

    seg_states = jax.vmap(seg_state)(kg, vg, lig, lfg)   # leaves (G,B,H,...)
    seg_states = tuple(_constrain_seq(s, cfg, 0) for s in seg_states)

    # ---- exclusive prefix over segments --------------------------------------
    inclusive = jax.lax.associative_scan(_compose_states, seg_states, axis=0)
    identity = (zeroC, zeron, zerom, jnp.zeros((B, H), jnp.float32))
    if state is not None:
        s0 = (state[0].astype(jnp.float32), state[1].astype(jnp.float32),
              state[2].astype(jnp.float32), jnp.zeros((B, H), jnp.float32))
    else:
        s0 = identity
    # exclusive shift (identity in front), then compose the incoming state
    shifted = tuple(
        jnp.concatenate([z[None], inc[:-1]], axis=0)
        for inc, z in zip(inclusive, identity))
    prefixes = jax.vmap(_compose_states, in_axes=(None, 0))(s0, shifted)
    prefixes = tuple(_constrain_seq(p_, cfg, 0) for p_ in prefixes)
    total = _compose_states(
        s0, tuple(x[-1] for x in inclusive))

    # ---- pass 2: per-segment chunk scans from the prefix ---------------------
    def seg_run(q_s, k_s, v_s, li_s, lf_s, pC, pn, pm):
        chunk = _make_chunk_fn(cfg, W, constrain=False)
        xs = {
            "q": q_s.reshape(B, nC, W, H, hd).swapaxes(0, 1),
            "k": k_s.reshape(B, nC, W, H, hd).swapaxes(0, 1),
            "v": v_s.reshape(B, nC, W, H, hd).swapaxes(0, 1),
            "li": li_s.reshape(B, nC, W, H).swapaxes(0, 1),
            "lf": lf_s.reshape(B, nC, W, H).swapaxes(0, 1),
        }
        _, hs = jax.lax.scan(jax.checkpoint(chunk), (pC, pn, pm), xs)
        return hs.swapaxes(0, 1).reshape(B, S_loc, H * hd)

    hg = jax.vmap(seg_run)(qg, kg, vg, lig, lfg,
                           prefixes[0], prefixes[1], prefixes[2])
    hg = _constrain_seq(hg, cfg, 0)                       # (G,B,S_loc,d_in)
    h = hg.swapaxes(0, 1).reshape(B, S, H * hd)
    return h, (total[0], total[1], total[2])


# =====================================================================
# sLSTM (xLSTM): scalar memory, sequential recurrence
# =====================================================================

def init_slstm(cfg: ModelConfig, key: jax.Array, dtype: Any) -> Params:
    D = cfg.d_model
    H = cfg.n_heads
    hd = D // H
    ks = jax.random.split(key, 10)
    p: Params = {"norm": jnp.ones((D,), dtype)}
    for i, g in enumerate(["z", "i", "f", "o"]):
        p[f"w_{g}"] = dense_init(ks[i], (D, D), dtype)
        p[f"r_{g}"] = dense_init(ks[4 + i], (H, hd, hd), dtype, scale=0.02)
        p[f"b_{g}"] = (jnp.full((D,), 3.0, jnp.float32) if g == "f"
                       else jnp.zeros((D,), jnp.float32))
    ff = int(D * 8 / 3) // 16 * 16
    p["ff_gate"] = dense_init(ks[8], (D, ff), dtype)
    p["ff_down"] = dense_init(ks[9], (ff // 2, D), dtype)
    return p


def run_slstm(p: Params, cfg: ModelConfig, x: jax.Array,
              state: Optional[Tuple[jax.Array, ...]] = None,
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, ...]]]:
    """x: (B,S,D). state = (c,n,h,m) each (B,H,hd)."""
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H

    def rec(h_prev: jax.Array, g: str) -> jax.Array:
        return jnp.einsum("bhi,hij->bhj", h_prev, p[f"r_{g}"].astype(jnp.float32))

    wz = (x @ p["w_z"] + p["b_z"].astype(x.dtype)).astype(jnp.float32)
    wi = (x @ p["w_i"] + p["b_i"].astype(x.dtype)).astype(jnp.float32)
    wf = (x @ p["w_f"] + p["b_f"].astype(x.dtype)).astype(jnp.float32)
    wo = (x @ p["w_o"] + p["b_o"].astype(x.dtype)).astype(jnp.float32)
    wz, wi, wf, wo = (w.reshape(B, S, H, hd) for w in (wz, wi, wf, wo))

    if state is None:
        zero = jnp.zeros((B, H, hd), jnp.float32)
        c0, n0, h0, m0 = zero, zero + 1e-6, zero, zero
    else:
        c0, n0, h0, m0 = (s.astype(jnp.float32) for s in state)

    def step(carry, t):
        c, n, h, m = carry
        z = jnp.tanh(wz[:, t] + rec(h, "z"))
        log_i = wi[:, t] + rec(h, "i")
        log_f = jax.nn.log_sigmoid(wf[:, t] + rec(h, "f"))
        o = jax.nn.sigmoid(wo[:, t] + rec(h, "o"))
        m1 = jnp.maximum(log_f + m, log_i)
        i1 = jnp.exp(log_i - m1)
        f1 = jnp.exp(log_f + m - m1)
        c1 = f1 * c + i1 * z
        n1 = f1 * n + i1
        h1 = o * c1 / jnp.maximum(n1, 1e-6)
        return (c1, n1, h1, m1), h1

    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), jnp.arange(S))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, D).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    # gated feed-forward (GeGLU, factor 4/3)
    ffg = y @ p["ff_gate"]
    a, b = jnp.split(ffg, 2, axis=-1)
    y = (jax.nn.gelu(a) * b) @ p["ff_down"]
    new_state = (c, n, h, m) if state is not None else None
    return y, new_state
