"""Encoder–decoder transformer (Whisper-style audio backbone).

The mel-spectrogram + conv frontend is STUBBED per the task carve-out:
``frames`` inputs are precomputed frame embeddings (B, enc_seq, d_source);
a linear projection stands in for the conv stack.  Everything downstream —
bidirectional encoder, causal decoder with cross-attention, KV-cached
decode — is implemented in full.

Deviation noted in DESIGN.md: rotary positions in the decoder (instead of
Whisper's learned absolute embeddings) so the decode cache code path is
shared with the rest of the zoo; the encoder keeps sinusoidal positions.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (Params, attention_scores, causal_mask, constrain_batch,
                     dense_init, init_attention, init_mlp, rms_norm,
                     run_attention, run_mlp)
from .config import ModelConfig


def _sinusoidal(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def init_cross_attention(cfg: ModelConfig, key: jax.Array, dtype: Any) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.q_dim), dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.kv_dim), dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.kv_dim), dtype),
        "wo": dense_init(ks[3], (cfg.q_dim, cfg.d_model), dtype),
    }


def cross_kv(p: Params, cfg: ModelConfig, enc_out: jax.Array,
             ) -> Tuple[jax.Array, jax.Array]:
    B, T, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.hd)
    return k, v


def run_cross_attention(p: Params, cfg: ModelConfig, x: jax.Array,
                        k: jax.Array, v: jax.Array) -> jax.Array:
    B, S, _ = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    rep = H // Hk
    kk = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vv = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    out = attention_scores(q, kk, vv, None)
    return out.reshape(B, S, H * hd) @ p["wo"]


# ---------------------------------------------------------------------- init

def init_params(cfg: ModelConfig, key: jax.Array,
                dtype: Any = jnp.float32) -> Params:
    ks = jax.random.split(key, 6)

    def enc_block(k: jax.Array) -> Params:
        k1, k2 = jax.random.split(k)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention(cfg, k1, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
        }

    def dec_block(k: jax.Array) -> Params:
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention(cfg, k1, dtype),
            "lnx": jnp.ones((cfg.d_model,), dtype),
            "xattn": init_cross_attention(cfg, k2, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
        }

    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_proj": dense_init(ks[2], (cfg.d_source, cfg.d_model), dtype),
        "enc_blocks": jax.vmap(enc_block)(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "embed": dense_init(ks[3], (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "dec_blocks": jax.vmap(dec_block)(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[4], (cfg.d_model, cfg.vocab), dtype),
    }


# ------------------------------------------------------------------- encoder

def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, enc_seq, d_source) stub embeddings → (B, enc_seq, D)."""
    x = frames @ params["enc_proj"]
    x = x + _sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    B, T, _ = x.shape
    positions = jnp.broadcast_to(
        jnp.zeros((T,), jnp.int32)[None], (B, T))   # rope disabled via pos=0

    def body(x, bp):
        x = constrain_batch(x, cfg)
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        out, _ = run_attention(bp["attn"], cfg, h, positions,
                               mask=jnp.zeros((1, 1, T, T), jnp.float32))
        x = x + out
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        return x + run_mlp(bp["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ------------------------------------------------------------------- decoder

def _dec_layers(params: Params, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array, enc_out: Optional[jax.Array],
                cache: Optional[Dict[str, Any]] = None,
                cache_len: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    def body(x, inputs):
        if cache is None:
            bp = inputs
            layer_cache = None
        else:
            bp, layer_cache = inputs
        x = constrain_batch(x, cfg)
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        kv = (layer_cache["k"], layer_cache["v"]) if layer_cache else None
        out, new_kv = run_attention(bp["attn"], cfg, h, positions, kv, cache_len)
        x = x + out
        h = rms_norm(x, bp["lnx"], cfg.norm_eps)
        if layer_cache is not None:
            ck, cv = layer_cache["xk"], layer_cache["xv"]
        else:
            ck, cv = cross_kv(bp["xattn"], cfg, enc_out)
        x = x + run_cross_attention(bp["xattn"], cfg, h, ck, cv)
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + run_mlp(bp["mlp"], h)
        if layer_cache is not None:
            nc = {"k": new_kv[0], "v": new_kv[1], "xk": ck, "xv": cv}
            return x, nc
        return x, None

    if cache is None:
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        return x, None
    x, new_layers = jax.lax.scan(body, x, (params["dec_blocks"], cache["layers"]))
    return x, {"layers": new_layers, "len": cache_len + x.shape[1]}


def forward(params: Params, cfg: ModelConfig,
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
    enc_out = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, _ = _dec_layers(params, cfg, x, positions, enc_out)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"], jnp.zeros((), jnp.float32)


def loss_fn(params: Params, cfg: ModelConfig,
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    from .decoder import cross_entropy

    logits, aux = forward(params, cfg, batch)
    ce, n_valid = cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux, "n_tokens": n_valid}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype: Any = jnp.float32) -> Dict[str, Any]:
    L, Hk, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    per = {
        "k": jnp.zeros((batch, max_len, Hk, hd), dtype),
        "v": jnp.zeros((batch, max_len, Hk, hd), dtype),
        "xk": jnp.zeros((batch, cfg.enc_seq, Hk, hd), dtype),
        "xv": jnp.zeros((batch, cfg.enc_seq, Hk, hd), dtype),
    }
    return {
        "len": jnp.zeros((), jnp.int32),
        "layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), per),
    }


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    enc_out = encode(params, cfg, batch["frames"])
    # compute cross K/V once, store in the cache
    def xkv(bp):
        return cross_kv(bp["xattn"], cfg, enc_out)
    xks, xvs = jax.vmap(xkv)(params["dec_blocks"])
    cache = dict(cache)
    layers = dict(cache["layers"])
    layers["xk"], layers["xv"] = xks, xvs
    cache["layers"] = layers
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, cache = _dec_layers(params, cfg, x, positions, None, cache, cache["len"])
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return (x @ params["lm_head"])[:, 0], cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    B = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    pos = jnp.broadcast_to(cache["len"][None, None], (B, 1)).astype(jnp.int32)
    x, cache = _dec_layers(params, cfg, x, pos, None, cache, cache["len"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x @ params["lm_head"])[:, 0], cache
