"""Unified decoder-only model covering dense / moe / ssm / hybrid / vlm.

Homogeneous stacks (dense, moe, hybrid, vlm) scan over stacked per-layer
params (MaxText-style) so lowering stays fast at 64 layers; the
heterogeneous xLSTM stack (mLSTM/sLSTM interleave) uses a python loop.

Three entry points per architecture:
  * ``forward``      — full-sequence logits (training / teacher forcing)
  * ``prefill``      — full-sequence + returns a decode-ready cache
  * ``decode_step``  — ONE token against the cache (the serve_step of the
                       decode_32k / long_500k shapes)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (Params, causal_mask, constrain_batch,
                     constrain_batch_seq, dense_init, init_attention,
                     init_mlp, rms_norm, run_attention, run_mlp)
from .config import ModelConfig
from .moe import init_moe, run_moe
from .ssm import init_mamba, init_mlstm, init_slstm, run_mamba, run_mlstm, run_slstm

CONV_K = 4


# ======================================================================
# init
# ======================================================================

def init_block(cfg: ModelConfig, key: jax.Array, dtype: Any) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if cfg.arch in ("dense", "vlm", "moe", "hybrid", "audio"):
        p["attn"] = init_attention(cfg, ks[0], dtype)
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        if cfg.arch == "moe":
            p["moe"] = init_moe(cfg, ks[1], dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        if cfg.arch == "hybrid":
            p["mamba"] = init_mamba(cfg, ks[2], dtype)
    elif cfg.arch == "ssm":
        p["mlstm"] = init_mlstm(cfg, ks[0], dtype)
        if cfg.slstm_every:
            p["slstm"] = init_slstm(cfg, ks[1], dtype)
    else:
        raise ValueError(cfg.arch)
    return p


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype: Any = jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    params: Params = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab), dtype)
    layer_keys = jax.random.split(ks[2], cfg.n_layers)
    if cfg.arch == "ssm":
        params["blocks"] = [init_block(cfg, k, dtype) for k in layer_keys]
    else:
        params["blocks"] = jax.vmap(
            lambda k: init_block(cfg, k, dtype))(layer_keys)
    return params


def _is_slstm(cfg: ModelConfig, layer: int) -> bool:
    return bool(cfg.slstm_every) and (layer % cfg.slstm_every == cfg.slstm_every - 1)


# ======================================================================
# block application
# ======================================================================

def run_block(cfg: ModelConfig, p: Params, x: jax.Array,
              positions: jax.Array,
              cache: Optional[Dict[str, jax.Array]] = None,
              cache_len: Optional[jax.Array] = None,
              layer_idx: int = 0) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]], jax.Array]:
    """One transformer-ish block.  Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[Dict[str, jax.Array]] = None
    seq_par = (cfg.arch == "ssm" and cfg.seq_segments > 1 and x.shape[1] > 1
               and x.shape[1] % (cfg.seq_segments * 256) == 0
               and not _is_slstm(cfg, layer_idx))
    x = constrain_batch_seq(x, cfg) if seq_par else constrain_batch(x, cfg)
    if cfg.arch in ("dense", "vlm", "moe", "hybrid", "audio"):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        kv = (cache["k"], cache["v"]) if cache is not None else None
        attn_out, new_kv = run_attention(p["attn"], cfg, h, positions, kv, cache_len)
        if cfg.arch == "hybrid":
            mstate = ((cache["h"], cache["conv"]) if cache is not None else None)
            ssm_out, new_mstate = run_mamba(p["mamba"], cfg, h, mstate)
            attn_out = 0.5 * (attn_out + ssm_out)
        x = x + attn_out
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.arch == "moe":
            ffn_out, aux = run_moe(p["moe"], cfg, h,
                                   use_kernel=cfg.use_flash_kernel,
                                   no_drop=cache is not None)
        else:
            ffn_out = run_mlp(p["mlp"], h)
        x = x + ffn_out
        if cache is not None:
            new_cache = {"k": new_kv[0], "v": new_kv[1]}
            if cfg.arch == "hybrid":
                new_cache["h"], new_cache["conv"] = new_mstate
    elif cfg.arch == "ssm":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if _is_slstm(cfg, layer_idx):
            st = ((cache["sc"], cache["sn"], cache["sh"], cache["sm"])
                  if cache is not None else None)
            out, new_st = run_slstm(p["slstm"], cfg, h, st)
            if cache is not None:
                new_cache = dict(cache)
                new_cache.update(zip(("sc", "sn", "sh", "sm"), new_st))
        else:
            st = ((cache["C"], cache["n"], cache["m"])
                  if cache is not None else None)
            out, new_st = run_mlstm(p["mlstm"], cfg, h, st)
            if cache is not None:
                new_cache = dict(cache)
                new_cache.update(zip(("C", "n", "m"), new_st))
        x = x + out
    else:
        raise ValueError(cfg.arch)
    return x, new_cache, aux


# ======================================================================
# full-sequence forward (train / prefill body)
# ======================================================================

def _embed(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
           ) -> Tuple[jax.Array, jax.Array]:
    """Returns (x (B,S,D), positions)."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.arch == "vlm" and "vision_embeds" in batch:
        # stubbed modality frontend: precomputed patch embeddings are
        # prepended to the text sequence (the carve-out in the task spec)
        x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x], axis=1)
    B, S = x.shape[0], x.shape[1]
    if cfg.mrope:
        positions = batch.get("positions3")
        if positions is None:
            base = jnp.arange(S)[None].astype(jnp.int32)
            positions = jnp.broadcast_to(base, (3, B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None].astype(jnp.int32), (B, S))
    return x, positions


def forward(params: Params, cfg: ModelConfig,
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence logits.  Returns (logits (B,S,V), aux_loss)."""
    x, positions = _embed(cfg, params, batch)

    if cfg.arch == "ssm":
        aux = jnp.zeros((), jnp.float32)
        for i, bp in enumerate(params["blocks"]):
            x, _, a = run_block(cfg, bp, x, positions, layer_idx=i)
            aux = aux + a
    else:
        def body(carry, bp):
            x, aux = carry
            fn = run_block
            if cfg.remat:
                fn = jax.checkpoint(
                    functools.partial(run_block), static_argnums=(0,))
            x, _, a = fn(cfg, bp, x, positions)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["blocks"])

    x = rms_norm(constrain_batch(x, cfg), params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    if cfg.arch == "vlm" and "vision_embeds" in batch:
        logits = logits[:, batch["vision_embeds"].shape[1]:]
    return logits, aux


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Fused CE: never materializes an f32 log-softmax of the full vocab —
    the label logit comes from a one-hot reduction (fuses to iota-compare-
    select-reduce, stays sharded on the vocab axis) and the normalizer is a
    streaming logsumexp."""
    V = logits.shape[-1]
    valid = labels >= 0
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), V, dtype=jnp.float32)
    label_logit = jnp.sum(lf * onehot, axis=-1)
    ll = label_logit - lse
    n_valid = jnp.maximum(jnp.sum(valid), 1)
    return -jnp.sum(ll * valid) / n_valid, n_valid


def loss_fn(params: Params, cfg: ModelConfig,
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, cfg, batch)
    ce, n_valid = cross_entropy(logits, batch["labels"])
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "n_tokens": n_valid}


# ======================================================================
# decode path
# ======================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype: Any = jnp.float32) -> Dict[str, Any]:
    """Cache pytree.  For sliding-window archs the KV store is a ring buffer
    of size ``window`` — this is what makes long_500k O(window) not O(seq)."""
    L, Hk, hd, H = cfg.n_layers, cfg.n_kv_heads, cfg.hd, cfg.n_heads
    kv_len = min(max_len, cfg.window) if cfg.window else max_len
    cache: Dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}

    def per_layer() -> Dict[str, jax.Array]:
        c: Dict[str, jax.Array] = {}
        if cfg.arch in ("dense", "vlm", "moe", "hybrid", "audio"):
            c["k"] = jnp.zeros((batch, kv_len, Hk, hd), dtype)
            c["v"] = jnp.zeros((batch, kv_len, Hk, hd), dtype)
        if cfg.arch == "hybrid":
            c["h"] = jnp.zeros((batch, cfg.d_in, cfg.ssm_state), jnp.float32)
            c["conv"] = jnp.zeros((batch, CONV_K - 1, cfg.d_in), dtype)
        if cfg.arch == "ssm":
            d_in = 2 * cfg.d_model
            hd_m = d_in // H
            hd_s = cfg.d_model // H
            c["C"] = jnp.zeros((batch, H, hd_m, hd_m), jnp.float32)
            c["n"] = jnp.zeros((batch, H, hd_m), jnp.float32)
            c["m"] = jnp.zeros((batch, H), jnp.float32)
            c["sc"] = jnp.zeros((batch, H, hd_s), jnp.float32)
            c["sn"] = jnp.zeros((batch, H, hd_s), jnp.float32) + 1e-6
            c["sh"] = jnp.zeros((batch, H, hd_s), jnp.float32)
            c["sm"] = jnp.zeros((batch, H, hd_s), jnp.float32)
        return c

    if cfg.arch == "ssm":
        cache["layers"] = [per_layer() for _ in range(L)]
    else:
        cache["layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), per_layer())
    return cache


def _apply_layers_cached(params: Params, cfg: ModelConfig, x: jax.Array,
                         positions: jax.Array, cache: Dict[str, Any],
                         ) -> Tuple[jax.Array, Dict[str, Any]]:
    cache_len = cache["len"]
    if cfg.arch == "ssm":
        new_layers = []
        for i, bp in enumerate(params["blocks"]):
            x, nc, _ = run_block(cfg, bp, x, positions, cache["layers"][i],
                                 cache_len, layer_idx=i)
            new_layers.append(nc)
        new_cache: Dict[str, Any] = {"layers": new_layers}
    else:
        def body(carry, inputs):
            x = carry
            bp, layer_cache = inputs
            x, nc, _ = run_block(cfg, bp, x, positions, layer_cache, cache_len)
            return x, nc

        x, new_layer_caches = jax.lax.scan(
            body, x, (params["blocks"], cache["layers"]))
        new_cache = {"layers": new_layer_caches}
    new_cache["len"] = cache_len + x.shape[1]
    return x, new_cache


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the prompt through the model, filling the cache.
    Returns (last-position logits (B,V), cache)."""
    x, positions = _embed(cfg, params, batch)
    x, cache = _apply_layers_cached(params, cfg, x, positions, cache)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return (x @ head)[:, 0], cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step: token (B,) int32 → (logits (B,V), cache)."""
    B = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    pos = jnp.broadcast_to(cache["len"][None, None], (B, 1)).astype(jnp.int32)
    if cfg.mrope:
        positions = jnp.broadcast_to(pos[None], (3, B, 1))
    else:
        positions = pos
    x, cache = _apply_layers_cached(params, cfg, x, positions, cache)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return (x @ head)[:, 0], cache
