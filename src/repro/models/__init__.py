"""Model zoo: a uniform functional interface over six architecture families.

``ops_for(cfg)`` returns the five entry points every layer above (training
loop, serving, dry-run) programs against:

    init(cfg, key, dtype)            -> params
    forward(params, cfg, batch)      -> (logits, aux)
    loss_fn(params, cfg, batch)      -> (loss, metrics)
    init_cache(cfg, B, max_len, dt)  -> cache
    prefill(params, cfg, batch, c)   -> (logits, cache)
    decode_step(params, cfg, tok, c) -> (logits, cache)
"""

from dataclasses import dataclass
from typing import Any, Callable

from . import decoder, encdec
from .config import ModelConfig


@dataclass(frozen=True)
class ModelOps:
    init: Callable
    forward: Callable
    loss_fn: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable


_DECODER_OPS = ModelOps(
    init=decoder.init_params,
    forward=decoder.forward,
    loss_fn=decoder.loss_fn,
    init_cache=decoder.init_cache,
    prefill=decoder.prefill,
    decode_step=decoder.decode_step,
)

_ENCDEC_OPS = ModelOps(
    init=encdec.init_params,
    forward=encdec.forward,
    loss_fn=encdec.loss_fn,
    init_cache=encdec.init_cache,
    prefill=encdec.prefill,
    decode_step=encdec.decode_step,
)


def ops_for(cfg: ModelConfig) -> ModelOps:
    if cfg.arch == "audio":
        return _ENCDEC_OPS
    if cfg.arch in ("dense", "moe", "ssm", "hybrid", "vlm"):
        return _DECODER_OPS
    raise ValueError(f"unknown arch family {cfg.arch}")


__all__ = ["ModelConfig", "ModelOps", "ops_for", "decoder", "encdec"]
