"""Memory-efficient (flash-style) attention in pure jnp, with custom VJP.

O(S·k_block) live memory instead of O(S²): the forward streams key/value
blocks with an online softmax; the backward recomputes block probabilities
from the saved (q, k, v, lse) instead of storing the S×S matrix.  This is
the same algorithm the Pallas TPU kernel (``repro.kernels.flash_attention``)
implements with explicit VMEM tiling — this jnp version is what the
dry-run lowers (Pallas-TPU can't lower on the CPU backend) and doubles as
the kernel's differentiable counterpart.

Supports causal masking with a query-position offset (cached prefill) and
sliding windows.  ``kpos``/``qpos`` are derived, not materialized.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_mask(qpos: jax.Array, kpos: jax.Array, window: int) -> jax.Array:
    """(Sq, Sk) additive mask for causal (+ optional window) attention."""
    ok = kpos[None, :] <= qpos[:, None]
    if window > 0:
        ok &= kpos[None, :] > (qpos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0,
                        q_offset_static: int = 0, k_block: int = 1024,
                        ) -> jax.Array:
    """q: (B,Sq,H,hd); k,v: (B,Sk,H,hd) — kv already head-repeated.
    Causal semantics: query i has absolute position q_offset+i; key j has
    position j."""
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset_static, k_block)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_offset, k_block,
                    ) -> Tuple[jax.Array, jax.Array]:
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    nkb = max(Sk // k_block, 1)
    kb = Sk // nkb
    assert Sk % nkb == 0, (Sq, Sk, kb)
    qf = q.astype(jnp.float32) * scale
    kf = k.reshape(B, nkb, kb, H, hd)
    vf = v.reshape(B, nkb, kb, H, hd)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, blk):
        acc, m, l = carry
        kb_, vb_ = blk["k"].astype(jnp.float32), blk["v"].astype(jnp.float32)
        kpos = blk["idx"] * kb + jnp.arange(kb)
        s = jnp.einsum("bqhd,bkhd->bqkh", qf, kb_)
        if causal:
            s = s + _block_mask(qpos, kpos, window)[None, :, :, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=2))
        p = jnp.exp(s - m_new[:, :, None, :])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=2)
        acc_new = acc * alpha[..., None] + jnp.einsum("bqkh,bkhd->bqhd", p, vb_)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    m0 = jnp.full((B, Sq, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, H), jnp.float32)
    blks = {"k": jnp.moveaxis(kf, 1, 0), "v": jnp.moveaxis(vf, 1, 0),
            "idx": jnp.arange(nkb)}
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), blks)
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


def _flash_fwd(q, k, v, causal, window, q_offset, k_block):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, k_block)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, k_block, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    nkb = max(Sk // k_block, 1)
    kb = Sk // nkb
    qf = q.astype(jnp.float32) * scale
    do = dout.astype(jnp.float32)
    # D_i = sum_d dout_i * out_i  (B,Sq,H)
    Dv = jnp.sum(do * out.astype(jnp.float32), axis=-1)
    qpos = q_offset + jnp.arange(Sq)
    kf = jnp.moveaxis(k.reshape(B, nkb, kb, H, hd), 1, 0)
    vf = jnp.moveaxis(v.reshape(B, nkb, kb, H, hd), 1, 0)

    def body(dq, blk):
        kb_ = blk["k"].astype(jnp.float32)
        vb_ = blk["v"].astype(jnp.float32)
        kpos = blk["idx"] * kb + jnp.arange(kb)
        s = jnp.einsum("bqhd,bkhd->bqkh", qf, kb_)
        if causal:
            s = s + _block_mask(qpos, kpos, window)[None, :, :, None]
        p = jnp.exp(s - lse[:, :, None, :])                 # (B,Sq,kb,H)
        dv = jnp.einsum("bqkh,bqhd->bkhd", p, do)
        dp = jnp.einsum("bqhd,bkhd->bqkh", do, vb_)
        ds = p * (dp - Dv[:, :, None, :])
        dq = dq + jnp.einsum("bqkh,bkhd->bqhd", ds, kb_) * scale
        dk = jnp.einsum("bqkh,bqhd->bkhd", ds, qf)          # qf has scale
        return dq, {"dk": dk, "dv": dv}

    dq0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    blks = {"k": kf, "v": vf, "idx": jnp.arange(nkb)}
    dq, outs = jax.lax.scan(body, dq0, blks)
    dk = jnp.moveaxis(outs["dk"], 0, 1).reshape(B, Sk, H, hd)
    dv = jnp.moveaxis(outs["dv"], 0, 1).reshape(B, Sk, H, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_jnp.defvjp(_flash_fwd, _flash_bwd)
