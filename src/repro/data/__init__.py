from .pipeline import SyntheticLM, ShardedLoader, make_batch_iterator

__all__ = ["SyntheticLM", "ShardedLoader", "make_batch_iterator"]
