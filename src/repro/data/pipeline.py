"""Data pipeline: deterministic synthetic LM stream + sharded loading.

The synthetic corpus is a mixture of Zipf-distributed unigrams and short
copy/induction motifs, so a ~100M model trained a few hundred steps shows a
real, monotone loss drop (the end-to-end example's acceptance criterion) —
white noise would pin the loss at log(V).

``ShardedLoader`` yields per-host shards of the global batch: each data-
parallel group reads only its slice, keyed by (step, shard) so every host is
deterministic and independent — no coordinator, in keeping with the paper's
decentralized setting.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    motif_len: int = 16
    n_motifs: int = 64
    zipf_a: float = 1.2

    def __post_init__(self) -> None:
        rng = np.random.default_rng(1234)
        self.motifs = rng.integers(
            0, self.vocab, size=(self.n_motifs, self.motif_len))
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = 1.0 / np.power(ranks, self.zipf_a)
        self.unigram = p / p.sum()

    def sample(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        toks = rng.choice(self.vocab, size=(batch, self.seq_len),
                          p=self.unigram)
        # plant repeated motifs (learnable structure: induction)
        n_plant = self.seq_len // (4 * self.motif_len)
        for b in range(batch):
            ids = rng.integers(0, self.n_motifs, size=n_plant)
            starts = rng.integers(
                0, max(self.seq_len - self.motif_len, 1), size=n_plant)
            for mid, st in zip(ids, starts):
                toks[b, st:st + self.motif_len] = self.motifs[mid]
        return toks.astype(np.int32)


@dataclass
class ShardedLoader:
    """Deterministic per-shard batches of {tokens, labels}."""

    source: SyntheticLM
    global_batch: int
    n_shards: int = 1
    shard: int = 0
    seed: int = 0

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        # independently seeded per (seed, step, shard): any host can compute
        # its slice with no coordination
        h = hashlib.sha256(
            f"{self.seed}/{step}/{self.shard}".encode()).digest()
        rng = np.random.default_rng(int.from_bytes(h[:8], "big"))
        toks = self.source.sample(rng, self.shard_batch)
        labels = np.concatenate(
            [toks[:, 1:], np.full((toks.shape[0], 1), -1, np.int32)], axis=1)
        return {"tokens": toks, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_iterator(vocab: int, seq_len: int, global_batch: int,
                        n_shards: int = 1, shard: int = 0,
                        seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    src = SyntheticLM(vocab=vocab, seq_len=seq_len)
    return iter(ShardedLoader(src, global_batch, n_shards, shard, seed))
