"""Lattica quickstart: build a NAT-mixed mesh and use every subsystem.

    PYTHONPATH=src python examples/quickstart.py

Walks through the paper's four scenarios at toy scale:
  1. connectivity across NATs (AutoNAT -> relay -> DCUtR upgrade)
  2. content-addressed artifact publish + swarm fetch (decentralized CDN)
  3. delta-aware checkpoints: per-tensor DAGs, so a new version only moves
     the tensors that changed (hierarchical v2 manifests)
  4. CRDT replicated store convergence
  5. concurrent serving: continuous batching over a 2-shard × 2-replica
     fleet, with pressure-driven replica spawn on the hot shard
  5b. the decode hot path: fused paged-attention decode (one weight pass
     per batch) vs the per-slot loop, int8 KV cache, and int8_block wire
     quantization for checkpoint sync
  6. a typed RPC service (MethodSpec-declared unary + streaming methods,
     called through a generated stub)
  7. the analysis plane: latlint rules + sanitized simulation
  8. fleet scale: a 1k-node virtual-clock fleet (Trautwein NAT mix) under
     churn — scored-mesh push delivery, Merkle-summarized anti-entropy,
     summary bytes and mesh relay load on the dashboard
  9. collaborative training: one DiLoCo-style round across 8 workers in
     2 regions joined by a thin link — H local steps, then a top-k +
     int8 compressed pseudo-gradient exchange coordinated entirely
     through the CRDT store (no coordinator), bytes-on-wire printed
"""

import sys

sys.path.insert(0, "src")

from repro.core import Service, streaming, unary
from repro.core.fleet import make_fleet
from repro.core.service import Fixed, pickled


def main():
    print("== building a 10-peer mesh behind mixed NATs ==")
    fleet = make_fleet(10, seed=7)
    sim = fleet.sim
    for n in fleet.peers[:5]:
        print(f"  {n.host.name}: nat={type(n.host.nat).__name__ if n.host.nat else 'public'}"
              f" reachability={n.transport.reachability}")

    a, b = fleet.peers[0], fleet.peers[5]

    # -- 1. connectivity ----------------------------------------------------
    def connect():
        conn = yield from a.connect_info(b.info())
        rtt = yield from a.transport.ping(conn)
        return conn, rtt

    conn, rtt = sim.run_process(connect())
    print(f"\n== 1. {a.host.name} -> {b.host.name}: "
          f"{'RELAYED' if conn.relayed else 'DIRECT'} path, rtt={rtt*1000:.1f}ms ==")

    # -- 1b. predicted-port punching through a symmetric NAT ----------------
    # A symmetric NAT mints a fresh external port per destination, so the
    # address it advertises is never the one it will use toward the peer —
    # naive hole punching always fails.  DCUtR v2 fingerprints the box's
    # port allocator by probing the relay from fresh sockets; against a
    # sequential (or fixed-delta) allocator the other side sprays the
    # predicted window base+stride*k and catches the fresh mapping.
    from repro.core import NATKind
    from repro.core.fleet import make_fleet as _mk

    sfleet = _mk(2, seed=11, nat_kinds=[
        (NATKind.SYMMETRIC, "sequential", 1),   # predictable allocator
        NATKind.PORT_RESTRICTED,                # strictest cone filter
    ])
    sym, cone = sfleet.peers

    def punch():
        c = yield from cone.connect_info(sym.info())
        return c

    sconn = sfleet.sim.run_process(punch())
    print(f"== 1b. symmetric(sequential) <- port_restricted: "
          f"{'RELAYED' if sconn.relayed else 'DIRECT (predicted-port punch)'}; "
          f"fingerprint probes={sym.transport.stats['fingerprint_probes']}, "
          f"predicted punches="
          f"{sym.transport.stats['predicted_punch_ok'] + cone.transport.stats['predicted_punch_ok']} ==")

    # -- 2. content distribution --------------------------------------------
    blob = bytes(range(256)) * 4096            # 1 MiB artifact

    def publish_fetch():
        root = yield from a.publish_artifact(blob, announce_topic="demo")
        t0 = sim.now
        got = yield from b.fetch_artifact(root)
        return root, got == blob, sim.now - t0

    root, ok, dt = sim.run_process(publish_fetch())
    print(f"== 2. published {len(blob)//1024} KiB as {root}; "
          f"fetched ok={ok} in {dt:.2f}s (sim) ==")

    # -- 3. delta-aware checkpoints -------------------------------------------
    # Each tensor becomes its own sub-DAG under a hierarchical manifest, so
    # version 2 reuses the unchanged tensors' CIDs verbatim: fetchers only
    # swarm the changed sub-DAGs, and publishers report the reuse fraction.
    import pickle

    import numpy as np

    from repro.checkpoint.lattica_ckpt import (fetch_checkpoint,
                                               publish_checkpoint)
    from repro.core.cid import decode_manifest_v2

    rng = np.random.default_rng(0)
    params_v1 = {f"layer{i}/w": rng.integers(0, 256, 96 * 1024, dtype=np.uint8)
                 for i in range(8)}
    params_v2 = dict(params_v1)
    params_v2["layer3/w"] = rng.integers(0, 256, 96 * 1024, dtype=np.uint8)

    def sync_versions():
        r1 = yield from publish_checkpoint(a, params_v1, 1, "quickstart")
        yield from fetch_checkpoint(b, r1, like=params_v1, fleet="quickstart")
        base_bytes = b.bitswap.stats["bytes_fetched"]
        r2 = yield from publish_checkpoint(a, params_v2, 2, "quickstart",
                                           base=r1)
        yield from fetch_checkpoint(b, r2, like=params_v1, fleet="quickstart")
        # latlint: disable=L003 locally-published manifest, not peer bytes
        meta = pickle.loads(decode_manifest_v2(a.blockstore.peek(r2))[2])
        return meta["delta"], b.bitswap.stats["bytes_fetched"] - base_bytes

    delta, v2_bytes = sim.run_process(sync_versions())
    print(f"== 3. checkpoint v2 (1 of 8 tensors changed): publisher reused "
          f"{delta['reused_bytes']//1024} KiB, new {delta['new_bytes']//1024} "
          f"KiB; fetcher moved only {v2_bytes//1024} KiB ==")

    # -- 3b. content-defined chunking ----------------------------------------
    # Fixed-size chunks lose all sharing the moment bytes *shift*: grow a
    # vocabulary and every downstream chunk gets a fresh CID.  A `cdc`
    # ChunkSpec places boundaries with a rolling hash, so they re-synchronize
    # right after the edit and the unchanged tail keeps its leaf CIDs.  The
    # spec is recorded in the manifest meta; publishing with base=<previous>
    # reuses it, so boundaries reproduce across versions.
    from repro.core.cid import ChunkSpec

    grown = {"vocab/w": np.concatenate(
        [rng.integers(0, 256, 2048, dtype=np.uint8), params_v1["layer0/w"]])}

    def shifted_edit(spec):
        r1 = yield from publish_checkpoint(
            a, {"vocab/w": params_v1["layer0/w"]}, 1, f"cdc-{spec.strategy}",
            spec=spec)
        r2 = yield from publish_checkpoint(
            a, grown, 2, f"cdc-{spec.strategy}", base=r1)
        # latlint: disable=L003 locally-published manifest, not peer bytes
        return pickle.loads(decode_manifest_v2(
            a.blockstore.peek(r2))[2])["delta"]

    for spec in (ChunkSpec(strategy="fixed", chunk_size=16 * 1024),
                 ChunkSpec.cdc(avg_size=16 * 1024)):
        d = sim.run_process(shifted_edit(spec))
        total = d["new_bytes"] + d["reused_bytes"]
        print(f"== 3b. {spec.strategy:>5} chunks, 2 KiB prepended to a 96 KiB "
              f"tensor: re-publish reuses {d['reused_bytes']/total:.0%} of "
              f"bytes ==")

    # -- 4. CRDT store: watch + delta push ------------------------------------
    # The replicated store is a *delta-state* CRDT document: every local
    # mutation ships as a minimal per-key delta on a crdt/<ns> pubsub
    # topic (canonical JSON, not pickle), so a subscriber's watch callback
    # fires one gossip round after a remote write — no anti-entropy tick,
    # no full-state swap.
    events = []
    b.watch_crdt("train/", lambda key, value, origin:
                 events.append((key, value, origin)))
    sim.run(until=sim.now + 2)       # subscription update reaches the mesh

    pushed0 = a.crdt_stats["push_bytes"]
    a.store.counter("train/steps").increment(a.host.name, 42)
    a.store.orset("train/ckpts").add("v1", a.host.name)
    sim.run(until=sim.now + 3)       # one gossip round
    print(f"== 4. CRDT delta push: {b.host.name} watch fired {events}; "
          f"subscriber sees steps="
          f"{b.store.counter('train/steps').value()}, "
          f"ckpts={b.store.orset('train/ckpts').value()}; "
          f"{a.crdt_stats['push_bytes'] - pushed0} B on the wire vs "
          f"{len(a.store.serialize())} B full state ==")

    # anti-entropy is the mop-up path, and it too moves per-key deltas
    # now: digest probe -> per-key digest summary -> delta transfer
    b.store.orset("train/ckpts").add("v2", b.host.name)

    def sync():
        yield from a.sync_crdt_with(b.info())

    sim.run_process(sync())
    print(f"== 4b. delta anti-entropy: ckpts={a.store.orset('train/ckpts').value()}, "
          f"rounds={a.crdt_stats['delta_exchanges']} delta / "
          f"{a.crdt_stats['full_exchanges']} full, "
          f"{a.crdt_stats['tx_bytes'] + a.crdt_stats['rx_bytes']} B total ==")

    # -- 5. concurrent serving: continuous batching + pressure replicas ------
    # Shard servers batch every live decode session into each RPC step
    # (paged KV slots, FIFO admission) and publish slot occupancy/queue
    # depth into the CRDT plane; an idle peer that observes sustained
    # hot-shard pressure fetches the shard's param sub-DAG off the
    # content plane and registers as a fresh DHT provider.
    import jax

    from repro.configs import get_config
    from repro.models import ops_for
    from repro.serving import PressureMonitor, ShardClient, serve_fleet

    scfg = get_config("granite-8b").reduced(n_layers=4, d_model=64, vocab=256)
    sparams = ops_for(scfg).init(scfg, jax.random.PRNGKey(0))
    sv_fleet = make_fleet(8, seed=23, same_region="us")
    ssim = sv_fleet.sim
    servers = ssim.run_process(
        serve_fleet(sv_fleet.peers[:4], scfg, sparams, "demo", replicas=2,
                    n_slots=2),
        until=ssim.now + 900)
    client = ShardClient(sv_fleet.peers[-1], scfg, "demo", n_shards=2)
    mon = PressureMonitor(sv_fleet.peers[5], scfg, "demo", hot_occupancy=0.5,
                          sustain=2, interval=0.3, n_slots=2)
    ssim.process(mon.run())
    prompts = [np.asarray(
        jax.random.randint(jax.random.PRNGKey(50 + i), (1, 8), 0, scfg.vocab),
        np.int32) for i in range(4)]

    def serve_demo():
        t0 = ssim.now
        reqs = [dict(tokens=prompts[i % len(prompts)], n_tokens=12)
                for i in range(12)]
        outs = yield from client.generate_concurrent(reqs)
        return outs, ssim.now - t0

    outs, sdt = ssim.run_process(serve_demo(), until=ssim.now + 3600)
    ssim.run(until=ssim.now + 30)          # let a pending spawn finish
    mon.stop()
    done = sum(1 for o in outs if o is not None)
    steps = sum(s.engine.stats["steps"] for s in servers)
    sess = sum(s.engine.stats["step_sessions"] for s in servers)
    print(f"== 5. serving: {done}/12 concurrent clients completed, "
          f"{done * 12 / sdt:.0f} tok/s, "
          f"{sess / max(1, steps):.1f} sessions/batched step; "
          f"pressure spawned {mon.stats['spawned']} replica(s) on "
          f"{sv_fleet.peers[5].host.name} ==")

    # -- 5b. fast decode + quantized hot paths --------------------------------
    # Each decode step above is ONE fused paged-attention pass over every
    # live session: the weights are read once per batch, and each slot's
    # KV pages are gathered from a shared pool (the Pallas kernel in
    # kernels/paged_attention.py; CPU runs the jnp formulation).  The
    # per-slot fallback pays a full weight read per session per token —
    # at decode, which is bandwidth-bound, that is the whole difference
    # (measured 6.4x tokens/s at 8 sessions: BENCH_decode_step.json).
    # `kv_dtype="int8"` stores pool pages quantized with per-page
    # per-kv-head scales: 0.38x the fp32 cache bytes, greedy tokens
    # identical at this scale.
    from repro.core.simnet import Sim as _Sim
    from repro.serving.batch import BatchEngine
    from repro.serving.sharded import ShardModule

    perf = {}
    for label, kw in (("fused", {}), ("unfused", {"fused": False}),
                      ("int8", {"kv_dtype": "int8"})):
        dsim = _Sim(seed=5)
        eng = BatchEngine(
            ShardModule(scfg, sparams, (0, scfg.n_layers), is_first=True,
                        is_last=True), dsim, n_slots=4, page_size=8, **kw)
        toks = {}
        for i in range(4):
            out, _ = dsim.run_process(eng.open(f"s{i}", prompts[i], 64))
            toks[f"s{i}"] = int(np.argmax(out[0]))
        cost, n_tok = 0.0, 0
        for _ in range(16):
            out, served, c = eng.step(
                list(toks), np.asarray([toks[s] for s in toks], np.int32))
            for sid, row in zip(served, out):
                toks[sid] = int(np.argmax(row))
            cost += c
            n_tok += len(served)
        perf[label] = (n_tok / cost, eng.kv_bytes())
    print(f"== 5b. decode: fused {perf['fused'][0]:.0f} tok/s vs per-slot "
          f"{perf['unfused'][0]:.0f} "
          f"({perf['fused'][0] / perf['unfused'][0]:.1f}x); int8 KV pool "
          f"{perf['int8'][1] / perf['fused'][1]:.2f}x fp32 cache bytes ==")

    # Checkpoint sync can quantize the *wire* the same way: int8 per
    # 4096-element block with f32 scale+zero-point, per-tensor parts, the
    # fp32 master staying lossless on the publisher.  Composed with the
    # delta plane (only churned tensors move at all), a 10%-churn sync
    # round moves ~0.25x the fp32 bytes (BENCH_model_sync.json).
    from repro.checkpoint import params_to_parts

    fp_bytes = sum(len(r) for _, r, _ in params_to_parts(sparams))
    q_bytes = sum(len(r) for _, r, _ in
                  params_to_parts(sparams, quant="int8_block"))
    print(f"== 5c. wire quantization: int8_block parts are "
          f"{q_bytes / fp_bytes:.2f}x the fp32 encoding "
          f"({fp_bytes // 1024} KiB -> {q_bytes // 1024} KiB), "
          f"error <= block_range/508 per element ==")

    # -- 6. typed RPC service -------------------------------------------------
    # Declare methods with MethodSpecs: wire name, codecs (which compute the
    # simulated wire size from the payload), idempotency and deadline.  The
    # handler returns just the response — no hand-passed size constants.
    class DemoService(Service):
        name = "demo"

        @unary("demo.double", request=Fixed(64), response=pickled(floor=64),
               idempotent=True, timeout=5.0)
        def double(self, payload, ctx):
            yield ctx.cpu(1e-6)
            return payload * 2

        @streaming("demo.squares")
        def squares(self, chan, ctx):
            for i in range(5):
                yield from chan.send(i * i, 64)
            chan.end()

    b.serve(DemoService())
    stub = a.stub(DemoService, b.info())   # reuses the existing connection

    def rpc():
        x = yield from stub.double(21)     # deadline + idempotent retry built in
        chan = yield from stub.squares()   # opens a backpressured channel
        got = []
        try:
            while True:
                got.append((yield from chan.recv(timeout=5.0)))
        except Exception:
            pass
        return x, got

    x, squares = sim.run_process(rpc())
    print(f"== 6. unary double(21)={x}; streamed squares={squares} ==")

    # -- fleet dashboard -------------------------------------------------------
    from repro.core.metrics import dashboard
    print("\n== fleet dashboard ==")
    print(dashboard(fleet.all_nodes))

    # -- 7. analysis plane -----------------------------------------------------
    # The repo lints itself: `python -m repro.analysis --strict` runs the
    # latlint rules (L001 no wall-clock/global-random in sim code, L002 no
    # raw RPC plane, L003 no unsafe pickle, L004 hedging only over
    # idempotent MethodSpecs, L005 generator-process hygiene, L006 Pallas
    # BlockSpec/grid/VMEM sanity); deliberate exceptions carry inline
    # `# latlint: disable=L00x <reason>` waivers.  Sanitized simulation is
    # one constructor flag away:
    from repro.analysis import run_lint
    report = run_lint([__file__])
    print(f"\n== 7. latlint on this example: "
          f"{'clean' if not report.active else report.format_text()} ==")

    from repro.core.simnet import Sim
    ssim = Sim(seed=7, sanitize=True)     # records an event-trace digest,
    ssim.run(until=1.0)                   # double-settles, orphans, leaks
    print(f"simsan digest (empty run): {ssim.trace_digest()[:16]}…  "
          "(CI double-runs serving/CRDT scenarios and diffs these)")

    # -- 8. fleet scale: 1k virtual-clock nodes under churn -------------------
    # make_scale_fleet skips per-node bootstrap: reachability comes from
    # the Trautwein et al. measured NAT mix, overlay edges are pre-wired,
    # so 1000 nodes stand up in about a second of wall time and churn
    # scenarios run entirely on the virtual clock.  A registry write
    # rides the scored gossipsub mesh to every subscriber; restarted
    # members catch up through Merkle-summarized anti-entropy (O(log n)
    # probe bytes instead of the flat per-key summary).
    import time

    from repro.core.fleet import make_scale_fleet

    t0 = time.time()    # latlint: disable=L001 host-side build timing
    kfleet = make_scale_fleet(1000, seed=3)
    ksim = kfleet.sim
    for n in kfleet.nodes:
        n.join_crdt_push("reg")
    ksim.run(until=ksim.now + 10)         # subscriptions + mesh formation
    writer = kfleet.publics[0]
    for i in range(4):
        writer.store.orset("reg/members").add(f"m{i}", writer.host.name)
    ksim.run(until=ksim.now + 6)          # ~3 gossip rounds
    reached = sum(1 for n in kfleet.nodes
                  if n.store.orset("reg/members").value())
    victims = kfleet.churn_wave(0.01)     # restart 1% of the NAT'd nodes
    hub = kfleet.publics[1]
    # a registry shard only the hub holds (its namespace has no push
    # subscribers): the restarted nodes pick it up via anti-entropy —
    # digest probe, then a Merkle summary-forest walk that localizes the
    # divergence in O(log n) probe bytes instead of a flat O(keys) round
    for i in range(64):
        hub.store.register(f"mreg/shard{i}").set(i, ksim.now, hub.host.name)

    def mop_up():
        for v in victims:
            yield from v.sync_crdt_with(hub.info())

    ksim.run_process(mop_up(), until=ksim.now + 120)
    probe = sum(n.crdt_stats["mst_probe_bytes"] for n in kfleet.nodes)
    probes = sum(n.crdt_stats["mst_exchanges"] for n in kfleet.nodes)
    print(f"\n== 8. fleet scale: 1000 nodes built+converged in "
          f"{time.time() - t0:.1f}s wall, "   # latlint: disable=L001 banner
          f"{ksim.now:.0f}s virtual; push reached {reached}/1000 nodes; "
          f"churned {len(victims)} nodes, anti-entropy mopped up with "
          f"{probe // max(1, probes)} B/probe ==")
    # the dashboard aggregates the new fleet gauges: mesh relay load
    # (max vs mean pubsub.forwarded — a healthy scored mesh keeps them
    # close) and summary_bytes (Merkle probe traffic); full per-node rows
    # are printed for a small sample only
    print("== 8b. dashboard (4-node sample of the 1k fleet) ==")
    print(dashboard([writer, hub] + victims[:2]))

    # -- 9. collaborative training round across 2 regions --------------------
    # DiLoCo-style: every worker runs H local AdamW steps, publishes its
    # pseudo-gradient top-k sparsified + int8-quantized as a content DAG,
    # and the round closes through CRDT quorum — no coordinator anywhere.
    # The regions= / bandwidth= knobs model two datacenters joined by a
    # thin transcontinental path; the compressed exchange is what makes
    # that link survivable.
    import jax

    from repro.configs import get_config
    from repro.data import make_batch_iterator
    from repro.optim import cosine_schedule
    from repro.train import train_state_init
    from repro.train.collab import CollabConfig, CollabWorker

    tcfg = get_config("minicpm-2b").reduced(n_layers=2, d_model=64, vocab=128)
    tfleet = make_scale_fleet(
        16, seed=21, nat_mix=[(None, 1.0)], regions=["us", "eu"],
        latency={"inter": 60e-3}, bandwidth={"inter": 1.2e7})
    tsim = tfleet.sim
    sched = cosine_schedule(1e-3, 5, 100)
    workers = []
    for i in range(8):
        data = make_batch_iterator(tcfg.vocab, 32, global_batch=8,
                                   n_shards=8, shard=i, seed=1)
        workers.append(CollabWorker(
            tfleet.nodes[i], tcfg,
            train_state_init(tcfg, jax.random.PRNGKey(0)), sched, data,
            "quickstart", collab=CollabConfig(inner_steps=6, settle=0.5),
            step_seconds=0.2))
    tprocs = [tsim.process(w.run(1)) for w in workers]
    tsim.run(until=tsim.now + 300)
    assert all(p.triggered and not p.failed for p in tprocs)
    wire = sum(w.stats["wire_bytes"] for w in workers)
    dense = sum(w.stats["dense_bytes"] for w in workers)
    digests = {w.outer_digest() for w in workers}
    regions = sorted({w.node.host.region for w in workers})
    print(f"\n== 9. collaborative round: 8 workers across {regions}, "
          f"H=6 inner steps ==")
    print(f"pseudo-gradient on the wire: {wire/1024:.0f} KiB compressed "
          f"vs {dense/1024:.0f} KiB naive fp32 ({wire/dense:.3f}x); "
          f"outer digests identical on all 8: {len(digests) == 1}")

    print(f"\nsim clock: {sim.now:.2f}s — done.")


if __name__ == "__main__":
    main()
