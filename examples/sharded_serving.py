"""Sharded AI inference over the Lattica DHT (paper Fig. 1, Scenario 4).

A 4-layer model is pipeline-split into 2 shards × 2 replicas, placed on
mesh peers (some behind NATs).  A client resolves shard providers through
the DHT, streams activations through the pipeline, and — when we kill a
shard server mid-service — fails over to the replica transparently.

    PYTHONPATH=src python examples/sharded_serving.py
"""

import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import make_fleet
from repro.models import ops_for
from repro.serving.sharded import ShardClient, deploy_sharded


def main():
    cfg = get_config("granite-8b").reduced(n_layers=4, d_model=128, vocab=512)
    ops = ops_for(cfg)
    params = ops.init(cfg, jax.random.PRNGKey(0))
    print(f"model: granite-8b family (reduced), "
          f"{sum(x.size for x in jax.tree.leaves(params))/1e6:.2f}M params")

    fleet = make_fleet(9, seed=99)
    sim = fleet.sim
    hosts = fleet.peers[:4]
    servers = deploy_sharded(hosts, cfg, params, "demo", replicas=2)
    print("placement:")
    for s in servers:
        print(f"  shard {s.shard_idx} (layers {s.module.lo}-{s.module.hi-1}"
              f"{' +embed' if s.module.is_first else ''}"
              f"{' +head' if s.module.is_last else ''}) on "
              f"{s.node.host.name} [{s.node.transport.reachability}]")

    def announce():
        for s in servers:
            yield from s.announce()

    sim.run_process(announce())

    client = ShardClient(fleet.peers[-1], cfg, "demo", n_shards=2)
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab), np.int32)

    def generate(n):
        t0 = sim.now
        out = yield from client.generate(prompt, n)
        return out, sim.now - t0

    out, dt = sim.run_process(generate(8))
    local, _ = ops.forward(params, cfg, {"tokens": jax.numpy.asarray(prompt)})
    print(f"\ngenerated (pipeline): {out[0].tolist()}  [{dt:.2f}s sim, "
          f"{dt/8*1000:.0f} ms/token]")

    print("\nkilling the serving shard-0 replica mid-generation ...")

    def generate_with_kill(n):
        t0 = sim.now
        gen = sim.process(generate(n))
        yield sim.timeout(dt / 2)           # let a few decode steps land
        victim = max((s for s in servers if s.shard_idx == 0 and s.alive),
                     key=lambda s: s.stats["decode"] + s.stats["prefill"])
        victim.stop()
        print(f"  killed {victim.node.host.name} mid-run")
        out, _ = yield gen
        return out, sim.now - t0

    out2, dt2 = sim.run_process(generate_with_kill(8), until=sim.now + 3600)
    print(f"generated (after failover): {out2[0].tolist()}  [{dt2:.2f}s sim]")
    print(f"client stats: {client.stats}")
    # the dead replica's sessions migrated (prefill replayed on the
    # survivor) and/or the retried call failed over — and greedy output
    # is unchanged by where it was computed
    assert client.stats["failovers"] + client.stats["sessions_migrated"] >= 1
    assert out2[0].tolist() == out[0].tolist()
    print("transparent DHT failover verified.")


if __name__ == "__main__":
    main()
