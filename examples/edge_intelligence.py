"""Edge intelligence under intermittent connectivity (paper §3, Scenario 1).

A smart-city-style fleet: a trainer in the "cloud" region publishes model
versions; edge devices (NAT'd, in another region) follow them.  Midway, the
WAN link between the regions PARTITIONS — the edge keeps serving its last
good model, the CRDT registry diverges safely, relay reservations die — and
after the link heals, maintenance re-reserves relays, anti-entropy
reconciles the registry, and the edge catches up to the latest version.

    PYTHONPATH=src python examples/edge_intelligence.py
"""

import sys

sys.path.insert(0, "src")

import jax

from repro.checkpoint.lattica_ckpt import (CheckpointRegistry,
                                           CheckpointService)
from repro.configs import get_config
from repro.core.fleet import make_fleet, wait_converged
from repro.core.metrics import dashboard
from repro.data import make_batch_iterator
from repro.optim import cosine_schedule
from repro.train import train_state_init
from repro.train.trainer import LatticaSyncTrainer, ModelSubscriber


def main():
    cfg = get_config("minicpm-2b").reduced(n_layers=2, d_model=128, vocab=1024)
    fleet = make_fleet(8, seed=61)
    sim = fleet.sim
    for n in fleet.peers:
        sim.process(n.maintenance_loop(interval=5.0))

    cloud = [n for n in fleet.peers if n.host.region == "us"][0]
    edges = [n for n in fleet.peers if n.host.region == "eu"][:2]
    print(f"cloud trainer: {cloud.host.name} (us); edge devices: "
          f"{[e.host.name for e in edges]} (eu, "
          f"{[e.transport.reachability for e in edges]})")

    state = train_state_init(cfg, jax.random.PRNGKey(0))
    data = make_batch_iterator(cfg.vocab, 64, 4, seed=3)
    trainer = LatticaSyncTrainer(
        cfg, state, cosine_schedule(2e-3, 5, 100), data,
        node=cloud, fleet="edge-city", publish_every=10, step_seconds=1.0)
    # resolve_from: edges poll the cloud's CheckpointService for 'latest';
    # during the partition the RPC fails and they fall back to local
    # knowledge (keep serving the stale model), after the heal one poll is
    # enough to catch up — no anti-entropy lottery
    subs = [ModelSubscriber(e, cfg, "edge-city", like=state.params,
                            resolve_from=cloud.info())
            for e in edges]
    sim.process(trainer.run_mesh(60, log=None))
    for s in subs:
        sim.process(s.follow(interval=4.0, until_step=59))

    # phase 1: connected — edges track the trainer
    sim.run(until=sim.now + 25)
    print(f"\n[t={sim.now:5.0f}s] connected: edge versions = "
          f"{[s.current_step for s in subs]} (trainer at step "
          f"{trainer.history[-1]['step'] + 1})")

    # phase 2: the WAN link dies
    fleet.net.set_partition("us", "eu", blocked=True)
    print(f"[t={sim.now:5.0f}s] *** us<->eu PARTITIONED ***")
    sim.run(until=sim.now + 20)
    stale = [s.current_step for s in subs]
    print(f"[t={sim.now:5.0f}s] partitioned: edges hold stale versions "
          f"{stale}; trainer kept publishing")

    # phase 3: heal — maintenance restores relays, the registry reconciles
    # via delta push + CheckpointService resolution; wait_converged pumps
    # the sim until every replica's digest agrees instead of guessing how
    # long "enough gossip" takes (the old source of flakiness)
    fleet.net.set_partition("us", "eu", blocked=False)
    print(f"[t={sim.now:5.0f}s] *** link healed ***")
    registries = wait_converged(sim, [cloud] + edges, timeout=240.0)
    print(f"[t={sim.now:5.0f}s] registry replicas converged = {registries}")
    sim.run(until=sim.now + 60)     # trailing fetches of the final version
    final = [s.current_step for s in subs]
    latest = CheckpointRegistry(cloud, "edge-city").latest()[0]
    print(f"[t={sim.now:5.0f}s] recovered: edge versions = {final}, "
          f"trainer latest = {latest}")
    assert all(f >= latest for f in final), "edges failed to catch up"
    # each edge agrees with the cloud's CheckpointService on 'latest'
    # (resolved over one RPC, not by waiting for register gossip)
    cloud_latest = CheckpointRegistry(cloud, "edge-city").latest()
    for s in subs:
        def resolve(s=s):
            stub = s.node.stub(CheckpointService, cloud.info())
            return (yield from stub.latest("edge-city"))
        assert sim.run_process(resolve(), until=sim.now + 60) == cloud_latest
        assert s.current_step == cloud_latest[0]
    print("\nlatest resolved consistently everywhere; "
          "edges caught up after heal.")
    print("\n== fleet dashboard ==")
    print(dashboard([cloud] + edges))


if __name__ == "__main__":
    main()
