"""End-to-end driver — the paper's RL pipeline (Fig. 1, Scenario 3).

A training cluster trains a language model on the synthetic corpus and
periodically publishes model versions into the Lattica mesh as
content-addressed chunks; two inference clusters behind NATs discover each
version via the CRDT registry + pubsub and swarm-fetch it with Bitswap.

    PYTHONPATH=src python examples/rl_fleet_sync.py               # ~10M model
    PYTHONPATH=src python examples/rl_fleet_sync.py --size 100m --steps 300

The default runs a reduced model so CPU wall-time stays in minutes; --size
100m is the full-scale variant of the same driver (same code path).
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.checkpoint.lattica_ckpt import (CheckpointRegistry,
                                           fetch_latest_from)
from repro.configs import get_config
from repro.core.fleet import make_fleet
from repro.data import make_batch_iterator
from repro.optim import wsd_schedule
from repro.train import train_state_init
from repro.train.trainer import LatticaSyncTrainer, ModelSubscriber


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=["small", "100m"], default="small")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--publish-every", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    if args.size == "100m":
        cfg = get_config("minicpm-2b").reduced(
            n_layers=10, d_model=768, vocab=32768)
    else:
        cfg = get_config("minicpm-2b").reduced(
            n_layers=4, d_model=256, vocab=4096)
    n_params = sum(x.size for x in jax.tree.leaves(
        train_state_init(cfg, jax.random.PRNGKey(0)).params))
    print(f"model: {cfg.name}-family, {n_params/1e6:.1f}M params")

    print("building mesh: 1 trainer cluster + 2 inference clusters "
          "(NAT-mixed) ...")
    fleet = make_fleet(8, seed=5)
    sim = fleet.sim
    trainer_node = fleet.peers[0]
    edge_a, edge_b = fleet.peers[-2], fleet.peers[-1]

    state = train_state_init(cfg, jax.random.PRNGKey(0))
    data = make_batch_iterator(cfg.vocab, args.seq, args.batch, seed=0)
    trainer = LatticaSyncTrainer(
        cfg, state, wsd_schedule(3e-3, 10, args.steps - 30, 20), data,
        node=trainer_node, fleet="rl-fleet",
        publish_every=args.publish_every, step_seconds=0.5)

    # resolve_from: followers ask the trainer's CheckpointService for the
    # latest version each poll instead of waiting for CRDT anti-entropy
    subs = [ModelSubscriber(n, cfg, "rl-fleet", like=state.params,
                            resolve_from=trainer_node.info())
            for n in (edge_a, edge_b)]
    procs = [sim.process(trainer.run_mesh(args.steps))]
    procs += [sim.process(s.follow(interval=3.0, until_step=args.steps - 1))
              for s in subs]
    sim.run(until=sim.now + 86400)

    print(f"\ntrainer: loss {trainer.history[0]['loss']:.3f} -> "
          f"{trainer.history[-1]['loss']:.3f} over {args.steps} steps, "
          f"{len(trainer.published)} versions published")
    latest_step, latest_root = CheckpointRegistry(
        trainer_node, "rl-fleet").latest()
    for s, name in zip(subs, ("edge_a", "edge_b")):
        log = s.fetch_log
        print(f"{name} ({s.node.host.name}, "
              f"{s.node.transport.reachability}): followed to step "
              f"{s.current_step}; {len(log)} fetches, last took "
              f"{log[-1]['t_fetch']:.2f}s (sim)")
        # converge on 'latest' via the trainer's CheckpointService (one
        # RPC) rather than waiting for CRDT anti-entropy to gossip the
        # register here; unchanged-tensor sub-DAGs make this fetch cheap
        def final_resolve(s=s):
            step, params = yield from fetch_latest_from(
                s.node, trainer_node.info(), "rl-fleet", like=state.params)
            return step, params
        step, params = sim.run_process(final_resolve(), until=sim.now + 600)
        assert step == latest_step, (
            f"{name} resolved step {step} != trainer latest {latest_step}")
        s.params = params
        s.current_step = step
    import numpy as np
    for s in subs:
        for a, b in zip(jax.tree.leaves(trainer.state.params),
                        jax.tree.leaves(s.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("edge clusters hold bit-identical latest params — "
          "registry + CDN path verified.")


if __name__ == "__main__":
    main()
