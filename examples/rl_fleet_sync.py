"""End-to-end driver — the paper's RL pipeline, now with no training
cluster at all.

The model is trained *collaboratively*: N workers scattered over the
NAT-mixed mesh run DiLoCo-style rounds (H local AdamW steps, then one
compressed pseudo-gradient exchange coordinated through the CRDT store —
no coordinator, no parameter server).  Because every worker applies the
identical outer step over the identical contribution set, outer params
are bit-identical fleet-wide; ANY worker can therefore publish each
round's outer params into the checkpoint registry, and the two inference
clusters behind NATs fetch them exactly as they fetched the old
single-trainer versions.

    PYTHONPATH=src python examples/rl_fleet_sync.py               # reduced
    PYTHONPATH=src python examples/rl_fleet_sync.py --size 100m --rounds 6

The default runs a reduced model so CPU wall-time stays in minutes; --size
100m is the full-scale variant of the same driver (same code path).
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint.lattica_ckpt import (CheckpointRegistry,
                                           fetch_latest_from,
                                           publish_checkpoint,
                                           serve_checkpoints)
from repro.configs import get_config
from repro.core.fleet import make_fleet
from repro.data import make_batch_iterator
from repro.optim import cosine_schedule
from repro.train import train_state_init
from repro.train.collab import CollabConfig, CollabWorker


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=["small", "100m"], default="small")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--inner-steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    if args.size == "100m":
        cfg = get_config("minicpm-2b").reduced(
            n_layers=10, d_model=768, vocab=32768)
    else:
        cfg = get_config("minicpm-2b").reduced(
            n_layers=4, d_model=256, vocab=4096)
    n_params = sum(x.size for x in jax.tree.leaves(
        train_state_init(cfg, jax.random.PRNGKey(0)).params))
    print(f"model: {cfg.name}-family, {n_params/1e6:.1f}M params")

    print(f"building mesh: {args.workers} collaborative workers + "
          "2 inference clusters (NAT-mixed) ...")
    fleet = make_fleet(args.workers + 4, seed=5)
    sim = fleet.sim
    edge_a, edge_b = fleet.peers[-2], fleet.peers[-1]

    sched = cosine_schedule(1e-3, 5, args.rounds * args.inner_steps + 50)
    eval_batch = next(make_batch_iterator(cfg.vocab, args.seq,
                                          args.batch, seed=999))
    ccfg = CollabConfig(inner_steps=args.inner_steps, settle=0.5,
                        outer_lr=0.4, outer_momentum=0.6)
    workers = []
    for i in range(args.workers):
        data = make_batch_iterator(cfg.vocab, args.seq, args.batch,
                                   n_shards=args.workers, shard=i, seed=0)
        workers.append(CollabWorker(
            fleet.peers[i], cfg, train_state_init(cfg, jax.random.PRNGKey(0)),
            sched, data, "rl-fleet", collab=ccfg, step_seconds=0.5,
            eval_batch=eval_batch if i == 0 else None))

    procs = [sim.process(w.run(args.rounds)) for w in workers]
    sim.run(until=sim.now + 86400)
    for p, w in zip(procs, workers):
        assert p.triggered and not p.failed, (w.name, p.value)

    digests = {w.outer_digest() for w in workers}
    assert len(digests) == 1, "outer state forked across the fleet"
    lead = workers[0]
    wire = sum(w.stats["wire_bytes"] for w in workers)
    dense = sum(w.stats["dense_bytes"] for w in workers)
    curve = " -> ".join(f"{r['eval_loss']:.3f}" for r in lead.round_log)
    print(f"\n{args.workers} workers x {args.rounds} rounds x "
          f"H={args.inner_steps}: eval loss {curve}")
    print(f"outer digests identical fleet-wide: {lead.outer_digest()[:16]}…")
    print(f"pseudo-gradient wire bytes: {wire/1e6:.2f} MB vs "
          f"{dense/1e6:.2f} MB naive fp32 ({wire/dense:.3f}x)")

    # any worker publishes the replicated outer params — they are all the
    # same bytes, so the registry sees one canonical version; serving the
    # checkpoint plane lets edges resolve "latest" with one RPC instead of
    # waiting for CRDT anti-entropy
    serve_checkpoints(lead.node)

    def publish():
        return (yield from publish_checkpoint(
            lead.node, lead.outer_params(), step=args.rounds, fleet="rl-fleet"))

    sim.run_process(publish(), until=sim.now + 600)
    latest_step, _ = CheckpointRegistry(lead.node, "rl-fleet").latest()
    print(f"published outer params as version step={latest_step}")

    for edge, name in ((edge_a, "edge_a"), (edge_b, "edge_b")):
        def fetch(edge=edge):
            step, params = yield from fetch_latest_from(
                edge, lead.node.info(), "rl-fleet", like=lead.outer_params())
            return step, params
        step, params = sim.run_process(fetch(), until=sim.now + 600)
        assert step == latest_step
        for a, b in zip(jax.tree.leaves(lead.outer_params()),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print(f"{name} ({edge.host.name}, {edge.transport.reachability}): "
              f"fetched step {step}, bit-identical to the fleet's outer "
              f"params")
    print("decentralized training + registry + CDN path verified.")


if __name__ == "__main__":
    main()
