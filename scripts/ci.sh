#!/usr/bin/env bash
# Reproducible test entrypoint: RPC throughput smoke check + tier-1 suite.
#   ./scripts/ci.sh                 run everything
#   SKIP_BENCH=1 ./scripts/ci.sh    tests only
#
# tests/test_kernels.py has known-failing seed tests; with a bare `-x` they
# would abort the run before most of the suite executes.  They are run
# separately, non-gating, so the rest of the suite is the hard gate.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [ -z "${SKIP_BENCH:-}" ]; then
    python benchmarks/rpc_throughput.py --smoke
fi

python -m pytest -x -q --ignore=tests/test_kernels.py

echo "--- kernels (known seed failures, non-gating) ---"
python -m pytest -q tests/test_kernels.py || true
