#!/usr/bin/env bash
# Reproducible test entrypoint: RPC throughput smoke + content-plane delta
# smoke + tier-1 suite (kernel tests run as their own gating step so a
# kernel failure still shows the rest of the suite's results).
#   ./scripts/ci.sh                  run everything
#   ./scripts/ci.sh --kernel-smoke   fast-decode + quantization gates only
#   ./scripts/ci.sh --lint           latlint + simsan determinism gates only
#   ./scripts/ci.sh --fleet-smoke    MST-efficiency + 1k-node churn gates only
#   ./scripts/ci.sh --train-smoke    collaborative-training round gates only
#   SKIP_BENCH=1 ./scripts/ci.sh     tests only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

kernel_smoke() {
    # fused paged-decode must beat the per-slot loop >=2x in tokens/s,
    # the int8 KV pool must hold <=0.55x the fp32 cache bytes with the
    # max logit deviation inside the stated bound (greedy path identical)
    python benchmarks/decode_step.py --kernel-smoke
    # int8_block wire quantization: a delta-sync round at 10% churn must
    # move <=0.3x the bytes the fp32 encoding moves (scales+zero-points
    # included), with the fp32 master staying lossless locally
    python benchmarks/model_sync.py --quant-smoke
    # receipts gate: every benchmark section must have emitted its
    # machine-readable BENCH_<group>.json artifact at the repo root
    python -m benchmarks.run --require-bench
}

fleet_smoke() {
    # MST anti-entropy efficiency: at 10k keys / 1% churn the Merkle walk's
    # probe bytes must be <=10% of the flat per-key summary a v2 round ships
    python benchmarks/crdt_sync.py --mst-smoke
    # 1k-node fleet under continuous churn (Trautwein NAT mix): >=99% push
    # delivery within 3 gossip rounds, relay load max <= 3x mean, every DHT
    # lookup finds its provider, >=99% registry pull coverage, <=60s wall
    python benchmarks/fleet_scale.py --fleet-smoke
}

train_smoke() {
    # collaborative (DiLoCo-style) rounds over a 2-region heterogeneous
    # fleet: outer loss within 5% of the single-node baseline at equal
    # total steps, compressed pseudo-gradient bytes <= 0.10x the fp32
    # full-exchange, a mid-run churn wave killing >= 2 workers with zero
    # aborted/lost rounds (rejoiners catch up onto the identical digest),
    # and a sanitizer double-run with bit-identical traces and zero
    # leaked contribution pins
    python benchmarks/collab_train.py --train-smoke
}

lint_gate() {
    # latlint: every rule (L001-L007) must be clean on the shipped tree —
    # violations are either fixed or carry a reasoned waiver
    # simsan: serving + CRDT-sync + churned-fleet scenarios must produce
    # bit-identical
    # event-trace digests across a double run, survive a seeded same-time
    # tie-break perturbation with the same functional result, and finish
    # with zero double-settles/orphans and a leak audit at baseline
    python -m repro.analysis --strict --determinism
}

if [ "${1:-}" = "--kernel-smoke" ]; then
    kernel_smoke
    exit 0
fi

if [ "${1:-}" = "--lint" ]; then
    lint_gate
    exit 0
fi

if [ "${1:-}" = "--fleet-smoke" ]; then
    fleet_smoke
    exit 0
fi

if [ "${1:-}" = "--train-smoke" ]; then
    train_smoke
    exit 0
fi

lint_gate

if [ -z "${SKIP_BENCH:-}" ]; then
    python benchmarks/rpc_throughput.py --smoke
    # content-plane delta smoke: correctness (reuse-fraction gate) is
    # gating, the printed timings are informational only
    python benchmarks/model_sync.py --delta-smoke
    # shifted-edit smoke: content-defined chunking must keep leaf-byte
    # reuse high when an insert shifts every downstream byte
    python benchmarks/model_sync.py --cdc-smoke
    # traversal smoke: mixed-NAT fleet (incl. symmetric peers) must reach
    # >=70% direct connectivity (relay fallback covering the rest), an
    # all-cone fleet >=95%, and PORT_RESTRICTED<->SYMMETRIC(sequential)
    # must upgrade via predicted-port punching
    python benchmarks/nat_traversal.py --punch-smoke
    # CRDT replication smoke: v2 delta sync must move <=10% of the bytes
    # the v1 full-state exchange moves at 1k keys / 1% churn, a pushed
    # write must reach every subscriber's watch callback within one gossip
    # round with no anti-entropy running, and v1<->v2 pairs must converge
    python benchmarks/crdt_sync.py --sync-smoke
    # serving smoke: concurrent clients through the continuous-batching
    # plane must beat the sequential v1 baseline >=3x, lose zero sessions
    # when a busy provider is killed mid-run (migration replays prefill on
    # a surviving replica), and pressure must spawn a hot-shard replica
    python benchmarks/sharded_inference.py --serve-smoke
    # fast-decode + quantized-sync gates (also runnable standalone via
    # ./scripts/ci.sh --kernel-smoke)
    kernel_smoke
    # MST probe-efficiency + 1k-node fleet churn gates (also standalone via
    # ./scripts/ci.sh --fleet-smoke)
    fleet_smoke
    # collaborative-training round gates (also standalone via
    # ./scripts/ci.sh --train-smoke)
    train_smoke
fi

python -m pytest -x -q --ignore=tests/test_kernels.py

python -m pytest -q tests/test_kernels.py
