"""Ad-hoc: run every reduced arch through forward/loss/prefill/decode."""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import ops_for


def main():
    only = sys.argv[1:] or ARCH_IDS
    for arch in only:
        t0 = time.time()
        cfg = get_config(arch).reduced()
        ops = ops_for(cfg)
        key = jax.random.PRNGKey(0)
        params = ops.init(cfg, key)
        B, S = 2, 32
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        if cfg.arch == "vlm":
            P = cfg.n_patches
            batch["vision_embeds"] = jax.random.normal(key, (B, P, cfg.d_model))
            batch["positions3"] = jnp.broadcast_to(
                jnp.arange(S + P, dtype=jnp.int32)[None, None], (3, B, S + P))
        if cfg.arch == "audio":
            batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_source))
        logits, aux = ops.forward(params, cfg, batch)
        assert logits.shape == (B, S, cfg.vocab), (arch, logits.shape)
        assert np.isfinite(np.asarray(logits)).all(), arch
        loss, metrics = ops.loss_fn(params, cfg, batch)
        assert np.isfinite(float(loss)), arch

        # prefill + 3 decode steps, compare against full forward
        extra = cfg.n_patches if cfg.arch == "vlm" else 0
        cache = ops.init_cache(cfg, B, S + 8 + extra)
        pre = {k: (v[:, :S - 4] if k in ("tokens", "labels") else v)
               for k, v in batch.items() if k != "labels"}
        if cfg.arch == "vlm":
            pre["positions3"] = batch["positions3"][:, :, :cfg.n_patches + S - 4]
        lg, cache = ops.prefill(params, cfg, pre, cache)
        errs = []
        for t in range(S - 4, S - 1):
            lg2, cache = ops.decode_step(params, cfg, batch["tokens"][:, t], cache)
            full = logits[:, t + (cfg.n_patches if cfg.arch == 'vlm' else 0) * 0]
            errs.append(float(jnp.max(jnp.abs(lg2 - logits[:, t]))))
        print(f"{arch:18s} loss={float(loss):7.3f} "
              f"decode-vs-forward maxerr={max(errs):.2e}  ({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
