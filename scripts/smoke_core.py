"""Ad-hoc smoke: build a small mesh, exercise every core subsystem."""
import sys

from repro.core import (LatticaNode, NATBox, NATKind, Network, Sim)


def main():
    sim = Sim(seed=7)
    net = Network(sim)
    # two public bootstrap/relay nodes + a mix of NAT'd peers
    boot1 = LatticaNode(net, "boot1", region="us", zone="a", serve_rendezvous=True)
    boot2 = LatticaNode(net, "boot2", region="us", zone="b")
    boot1.transport.enable_relay()
    boot2.transport.enable_relay()
    nodes = [boot1, boot2]
    kinds = [NATKind.FULL_CONE, NATKind.RESTRICTED_CONE,
             NATKind.PORT_RESTRICTED, NATKind.SYMMETRIC, None, None]
    for i, kind in enumerate(kinds):
        nat = NATBox(net, kind) if kind else None
        n = LatticaNode(net, f"peer{i}", region="eu" if i % 2 else "us",
                        zone="a", nat=nat)
        nodes.append(n)

    # bootstrap servers interconnect (needed for sound AutoNAT forwarding)
    sim.run_process(boot2.connect_info(boot1.info()))
    binfos = [boot1.info(), boot2.info()]

    def join(n):
        reach = yield from n.bootstrap(binfos)
        return reach

    for n in nodes[2:]:
        reach = sim.run_process(join(n), until=sim.now + 60)
        print(f"{n.host.name}: reachability={reach} rt_size={len(n.dht.table)}")

    # DHT put/get across the mesh
    def put_get():
        key = b"k" * 32
        yield from nodes[2].dht.put(key, "hello-lattica")
        val = yield from nodes[-1].dht.get(key)
        return val

    print("dht get:", sim.run_process(put_get(), until=sim.now + 120))

    # artifact publish + fetch (bitswap) between two NAT'd peers
    def artifact():
        data = bytes(range(256)) * 4096  # 1 MiB
        root = yield from nodes[3].publish_artifact(data, announce_topic="models")
        got = yield from nodes[5].fetch_artifact(root)
        return root, got == data

    root, ok = sim.run_process(artifact(), until=sim.now + 300)
    print("bitswap fetch ok:", ok, root)

    # CRDT sync
    def crdt():
        nodes[2].store.counter("steps").increment("peer0", 10)
        nodes[4].store.counter("steps").increment("peer2", 5)
        yield from nodes[2].sync_crdt_with(nodes[4].info())
        return (nodes[2].store.counter("steps").value(),
                nodes[4].store.counter("steps").value())

    print("crdt:", sim.run_process(crdt(), until=sim.now + 60))

    # hole punch stats
    for n in nodes:
        s = n.transport.stats
        if any(s.values()):
            print(n.host.name, s)
    print("sim time:", round(sim.now, 3), "s")


if __name__ == "__main__":
    main()
