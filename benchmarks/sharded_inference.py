"""Sharded inference over the mesh (paper Fig. 1-4): pipeline throughput,
per-token latency, and failover cost when a shard dies mid-service.

``main_serving`` benchmarks the continuous-batching plane: N concurrent
clients against a 2-shard × 2-replica fleet, sequential v1 baseline vs
batched v2 tokens/s, p50/p95 request latency, one provider killed
mid-run (must lose zero sessions), and a pressure-spawned hot-shard
replica.  Emits ``BENCH_serving.json``; ``--serve-smoke`` runs the
reduced gating variant used by CI.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import make_fleet
from repro.models import ops_for
from repro.serving.pressure import PressureMonitor
from repro.serving.sharded import ShardClient, deploy_sharded, serve_fleet

try:
    from . import _bench
except ImportError:         # standalone: benchmarks/ itself is on sys.path
    import _bench


def main(report: List[str]) -> Dict[str, Any]:
    cfg = get_config("granite-8b").reduced(n_layers=4, d_model=128, vocab=512)
    ops = ops_for(cfg)
    params = ops.init(cfg, jax.random.PRNGKey(0))
    fleet = make_fleet(9, seed=99, same_region="us")
    sim = fleet.sim
    servers = deploy_sharded(fleet.peers[:4], cfg, params, "bench",
                             replicas=2)

    def announce() -> Generator:
        for s in servers:
            yield from s.announce()

    sim.run_process(announce(), until=sim.now + 600)
    client = ShardClient(fleet.peers[-1], cfg, "bench", n_shards=2)
    toks = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab),
        np.int32)

    def generate(n_tokens: int) -> Generator:
        t0 = sim.now
        out = yield from client.generate(toks, n_tokens)
        return out, sim.now - t0

    out, t_gen = sim.run_process(generate(16), until=sim.now + 3600)
    per_tok = t_gen / 16
    report.append("# Sharded inference (2 shards × 2 replicas, reduced model)")
    report.append(f"prefill+16 decode steps: {t_gen:.3f}s "
                  f"({per_tok*1000:.1f} ms/token, batch=4)")

    # failover: kill shard-0 replica used so far, measure next-token latency
    dead = [s for s in servers if s.shard_idx == 0][0]
    dead.stop()
    t0 = sim.now

    def one_more() -> Generator:
        out = yield from client.generate(toks, 1)
        return out

    sim.run_process(one_more(), until=sim.now + 3600)
    failover_ms = (sim.now - t0) * 1000
    report.append(f"failover token (shard replica killed): "
                  f"{failover_ms:.1f} ms "
                  f"(failovers={client.stats['failovers']})")
    return {"gen_time_s": t_gen, "ms_per_token": per_tok * 1000,
            "failover_ms": failover_ms,
            "failovers": client.stats["failovers"]}


def main_serving(report: List[str], smoke: bool = False) -> Dict[str, Any]:
    """Continuous-batching serving benchmark (BENCH_serving.json)."""
    n_clients = 32 if smoke else 104
    n_tokens = 24 if smoke else 16
    seq_probe = 4 if smoke else 8
    kill_at = 0.3 if smoke else 0.5
    stagger = 0.01
    n_slots = 8

    cfg = get_config("granite-8b").reduced(n_layers=4, d_model=64, vocab=256)
    ops = ops_for(cfg)
    params = ops.init(cfg, jax.random.PRNGKey(0))
    fleet = make_fleet(12, seed=7, same_region="us")
    sim = fleet.sim
    servers = sim.run_process(
        serve_fleet(fleet.peers[:4], cfg, params, "bench", replicas=2,
                    n_slots=n_slots),
        until=sim.now + 900)
    prompts = [np.asarray(
        jax.random.randint(jax.random.PRNGKey(100 + i), (1, 8), 0, cfg.vocab),
        np.int32) for i in range(8)]

    # -- sequential baseline: same fleet, one v1 request at a time ----------
    seq_client = ShardClient(fleet.peers[-1], cfg, "bench", n_shards=2)

    def sequential() -> Generator:
        t0 = sim.now
        for i in range(seq_probe):
            yield from seq_client.generate(prompts[i % len(prompts)],
                                           n_tokens)
        return sim.now - t0

    seq_time = sim.run_process(sequential(), until=sim.now + 3600)
    seq_tps = seq_probe * n_tokens / seq_time

    # -- batched: N concurrent clients, provider kill + pressure monitor ----
    client = ShardClient(fleet.peers[-2], cfg, "bench", n_shards=2)
    mon = PressureMonitor(fleet.peers[6], cfg, "bench", hot_occupancy=0.5,
                          sustain=2, interval=0.25, max_replicas=3,
                          n_slots=n_slots)
    sim.process(mon.run())

    latencies: List[float] = []
    killed: List[Any] = []

    def one_client(i: int) -> Generator:
        yield sim.timeout(i * stagger)
        t0 = sim.now
        ev = client.submit(prompts[i % len(prompts)], n_tokens)
        out = yield ev
        if out is not None:
            latencies.append(sim.now - t0)

    def killer() -> Generator:
        yield sim.timeout(kill_at)  # mid-run: admissions have landed
        busy = [s for s in servers
                if s.alive and s.shard_idx == 0 and s.engine.slots_used > 0]
        if busy:
            busy[0].stop()
            killed.append(busy[0])

    def batched() -> Generator:
        t0 = sim.now
        procs = [sim.process(one_client(i)) for i in range(n_clients)]
        sim.process(killer())
        for p in procs:
            yield p
        return sim.now - t0

    bat_time = sim.run_process(batched(), until=sim.now + 3600)
    # grace: a spawn decision taken on the last hot tick still needs sim
    # time to fetch the shard params off the content plane and announce;
    # with the load generators gone this same window is the cold drain —
    # sustained-cold detection retires the monitor-spawned replica and
    # the serving plane returns to its deployed baseline
    sim.run(until=sim.now + 30)
    mon.stop()
    replica_sets = {shard: mon.replica_count(shard) for shard in (0, 1)}
    bat_tps = n_clients * n_tokens / bat_time
    lat = np.asarray(sorted(latencies))
    p50 = float(lat[int(0.50 * (len(lat) - 1))]) if len(lat) else float("nan")
    p95 = float(lat[int(0.95 * (len(lat) - 1))]) if len(lat) else float("nan")

    metrics: Dict[str, Any] = {
        "smoke": smoke,
        "fleet": {"shards": 2, "replicas": 2, "n_slots": n_slots},
        "n_clients": n_clients,
        "n_tokens": n_tokens,
        "sequential_tokens_per_s": seq_tps,
        "batched_tokens_per_s": bat_tps,
        "speedup": bat_tps / seq_tps,
        "latency_p50_s": p50,
        "latency_p95_s": p95,
        "completed": client.stats["completed"],
        "failed_sessions": client.stats["failed_sessions"],
        "sessions_migrated": client.stats["sessions_migrated"],
        "failovers": client.stats["failovers"],
        "provider_killed": bool(killed),
        "replicas_spawned": mon.stats["spawned"],
        "replicas_retired": mon.stats["retired"],
        "monitor_replicas_live": len(mon.spawned),
        # deployed baseline is 2 replicas per shard; after the cold drain
        # every monitor-spawned replica must have left the replica set
        "replica_sets_after_drain": replica_sets,
        "slots_back_to_baseline": all(c == 2 for c in replica_sets.values()),
        "pressure": mon.stats,
    }
    report.append(f"# Serving: {n_clients} concurrent clients, "
                  f"2 shards x 2 replicas, {n_slots} slots/replica")
    report.append(f"sequential v1: {seq_tps:8.1f} tok/s "
                  f"({seq_probe} requests probed)")
    report.append(f"batched v2:   {bat_tps:8.1f} tok/s "
                  f"({metrics['speedup']:.1f}x, "
                  f"p50={p50*1000:.0f}ms p95={p95*1000:.0f}ms)")
    report.append(f"provider killed mid-run: {bool(killed)}  "
                  f"failed={metrics['failed_sessions']} "
                  f"migrated={metrics['sessions_migrated']}")
    report.append(f"pressure: spawned {mon.stats['spawned']} replica(s) "
                  f"on hot shards, retired {mon.stats['retired']} after "
                  f"the cold drain (replica sets: {replica_sets})")
    return metrics


if __name__ == "__main__":
    import sys
    out: List[str] = []
    if "--serve-smoke" in sys.argv[1:]:
        metrics = main_serving(out, smoke=True)
        _bench.emit("serving_smoke", metrics)
        print("\n".join(out))
        assert metrics["speedup"] >= 3.0, \
            f"batching gain {metrics['speedup']:.2f}x < 3x"
        assert metrics["provider_killed"], "no provider was killed mid-run"
        assert metrics["failed_sessions"] == 0, \
            f"{metrics['failed_sessions']} sessions failed after provider kill"
        assert metrics["replicas_spawned"] >= 1, "pressure spawned no replica"
        assert metrics["replicas_retired"] >= 1, \
            "cold drain retired no replica"
        assert metrics["monitor_replicas_live"] == 0, \
            "monitor still holds live replicas after the drain"
        assert metrics["slots_back_to_baseline"], \
            f"replica sets never returned to baseline: " \
            f"{metrics['replica_sets_after_drain']}"
        print("smoke: OK")
    else:
        main(out)
        metrics = main_serving(out)
        _bench.emit("serving", metrics)
        print("\n".join(out))
