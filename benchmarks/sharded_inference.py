"""Sharded inference over the mesh (paper Fig. 1-4): pipeline throughput,
per-token latency, and failover cost when a shard dies mid-service."""

from __future__ import annotations

from typing import Generator, List

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import make_fleet
from repro.models import ops_for
from repro.serving.sharded import ShardClient, deploy_sharded


def main(report: List[str]) -> None:
    cfg = get_config("granite-8b").reduced(n_layers=4, d_model=128, vocab=512)
    ops = ops_for(cfg)
    params = ops.init(cfg, jax.random.PRNGKey(0))
    fleet = make_fleet(9, seed=99, same_region="us")
    sim = fleet.sim
    servers = deploy_sharded(fleet.peers[:4], cfg, params, "bench",
                             replicas=2)

    def announce() -> Generator:
        for s in servers:
            yield from s.announce()

    sim.run_process(announce(), until=sim.now + 600)
    client = ShardClient(fleet.peers[-1], cfg, "bench", n_shards=2)
    toks = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab),
        np.int32)

    def generate(n_tokens: int) -> Generator:
        t0 = sim.now
        out = yield from client.generate(toks, n_tokens)
        return out, sim.now - t0

    out, t_gen = sim.run_process(generate(16), until=sim.now + 3600)
    per_tok = t_gen / 16
    report.append("# Sharded inference (2 shards × 2 replicas, reduced model)")
    report.append(f"prefill+16 decode steps: {t_gen:.3f}s "
                  f"({per_tok*1000:.1f} ms/token, batch=4)")

    # failover: kill shard-0 replica used so far, measure next-token latency
    dead = [s for s in servers if s.shard_idx == 0][0]
    dead.stop()
    t0 = sim.now

    def one_more() -> Generator:
        out = yield from client.generate(toks, 1)
        return out

    sim.run_process(one_more(), until=sim.now + 3600)
    report.append(f"failover token (shard replica killed): "
                  f"{(sim.now - t0)*1000:.1f} ms "
                  f"(failovers={client.stats['failovers']})")


if __name__ == "__main__":
    out: List[str] = []
    main(out)
    print("\n".join(out))
