"""Paper Table 1: RPC throughput at 1000 concurrent calls (QPS).

Client and server are 4-core hosts on the four network scenarios; each
worker issues sequential unary calls through a typed service stub over the
shared secured connection.  The CPU-bound rows (Local, LAN) reproduce the
paper's numbers from the calibrated per-message/per-byte costs; the WAN rows
are latency/bandwidth bound (see EXPERIMENTS.md for the deviation analysis —
the simulator omits TCP congestion dynamics, so small-payload WAN rows run
faster than the paper's measurement).

``--smoke`` runs a reduced matrix (one CPU-bound scenario, lower
concurrency) as a CI sanity check.
"""

from __future__ import annotations

import sys
from typing import Dict, Generator, List, Tuple

from repro.core import LatticaNode, Network, Sim
from repro.core.rpc import RpcContext
from repro.core.service import ByteLength, Fixed, Service, unary

CONCURRENCY = 1000
CALLS_PER_WORKER = 4

#: scenario name -> (regions, zones, machine tags) for the two hosts
SCENARIOS = {
    "local_same_host": (("us", "us"), ("a", "a"), ("m1", "m1")),
    "same_region_lan": (("us", "us"), ("a", "a"), (None, None)),
    "same_region_wan": (("us", "us"), ("a", "b"), (None, None)),
    "inter_continent": (("us", "ap"), ("a", "x"), (None, None)),
}

PAPER_TABLE1 = {  # scenario -> (qps @128B, qps @256KB)
    "local_same_host": (10000, 850),
    "same_region_lan": (8000, 600),
    "same_region_wan": (3000, 280),
    "inter_continent": (1200, 110),
}


class EchoService(Service):
    """Ping-style echo: tiny request, ``payload``-sized response (one-way
    payload, matching the paper's measurement)."""

    name = "bench"

    def __init__(self, payload: int):
        self.blob = b"\0" * payload

    @unary("bench.echo", request=Fixed(96), response=ByteLength(),
           idempotent=True, timeout=600.0)
    def echo(self, req, ctx: RpcContext) -> Generator:
        yield ctx.cpu(0)
        return self.blob


def measure(scenario: str, payload: int, seed: int = 0,
            concurrency: int = CONCURRENCY) -> float:
    regions, zones, machines = SCENARIOS[scenario]
    sim = Sim(seed=seed)
    net = Network(sim)
    client = LatticaNode(net, "client", region=regions[0], zone=zones[0],
                         machine=machines[0])
    server = LatticaNode(net, "server", region=regions[1], zone=zones[1],
                         machine=machines[1])
    server.serve(EchoService(payload))

    def run() -> Generator:
        yield from client.connect_info(server.info())
        stub = client.stub(EchoService, server.info())
        done = {"n": 0}

        def worker() -> Generator:
            for _ in range(CALLS_PER_WORKER):
                yield from stub.echo(b"q")
                done["n"] += 1

        t0 = sim.now
        procs = [sim.process(worker()) for _ in range(concurrency)]
        yield sim.all_of(procs)
        elapsed = sim.now - t0
        return done["n"] / elapsed

    return sim.run_process(run(), until=sim.now + 36000)


def main(report: List[str], smoke: bool = False) -> Dict[str, object]:
    scenarios = ["local_same_host"] if smoke else list(SCENARIOS)
    concurrency = 100 if smoke else CONCURRENCY
    report.append("# Table 1 — RPC throughput, "
                  f"{concurrency} concurrent calls (QPS)")
    report.append(f"{'scenario':<18} {'payload':>8} {'sim_qps':>9} "
                  f"{'paper_qps':>9} {'ratio':>6}")
    rows = []
    for scenario in scenarios:
        for payload, col in ((128, 0), (256 * 1024, 1)):
            qps = measure(scenario, payload, concurrency=concurrency)
            paper = PAPER_TABLE1[scenario][col]
            rows.append({"scenario": scenario, "payload": payload,
                         "sim_qps": qps, "paper_qps": paper,
                         "ratio": qps / paper})
            report.append(f"{scenario:<18} {payload:>8} {qps:>9.0f} "
                          f"{paper:>9} {qps / paper:>6.2f}")
    if smoke:
        report.append("smoke: OK")
    return {"concurrency": concurrency, "rows": rows}


if __name__ == "__main__":
    out: List[str] = []
    main(out, smoke="--smoke" in sys.argv[1:])
    print("\n".join(out))
