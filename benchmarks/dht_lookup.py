"""DHT scaling: iterative-lookup rounds vs network size (O(log N))."""

from __future__ import annotations

import hashlib
from typing import Dict, Generator, List

from repro.core.fleet import make_fleet


def main(report: List[str]) -> Dict[str, object]:
    report.append("# Kademlia lookup cost vs N (paper: O(log N))")
    report.append(f"{'N':>5} {'avg_rounds':>10} {'avg_queries':>11} "
                  f"{'avg_latency_s':>13}")
    rows = []
    for n in (8, 16, 32, 64):
        fleet = make_fleet(n, seed=31, same_region="us")
        sim = fleet.sim
        node = fleet.peers[0]
        node.dht.stats.update({"rounds": 0, "queries": 0, "lookups": 0})
        t_total = 0.0
        n_lookups = 10
        for i in range(n_lookups):
            key = hashlib.sha256(f"key-{i}".encode()).digest()

            def lookup(key=key) -> Generator:
                t0 = sim.now
                yield from node.dht.find_node(key)
                return sim.now - t0

            t_total += sim.run_process(lookup(), until=sim.now + 600)
        s = node.dht.stats
        rows.append({"n": n, "avg_rounds": s["rounds"] / n_lookups,
                     "avg_queries": s["queries"] / n_lookups,
                     "avg_latency_s": t_total / n_lookups})
        report.append(f"{n:>5} {s['rounds']/n_lookups:>10.1f} "
                      f"{s['queries']/n_lookups:>11.1f} "
                      f"{t_total/n_lookups:>13.4f}")
    return {"lookups": rows}


if __name__ == "__main__":
    out: List[str] = []
    main(out)
    print("\n".join(out))
