"""Machine-readable benchmark artifacts.

Every section in :mod:`benchmarks.run` may return a metrics dict; the
orchestrator writes it to ``BENCH_<section>.json`` at the repo root so CI
and downstream tooling diff runs without scraping the text report.
Sections run standalone (``python benchmarks/<x>.py``) emit through the
same helper.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict

__all__ = ["emit"]

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _jsonable(obj: Any) -> Any:
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, bool)) or obj is None:
        return obj
    if isinstance(obj, float):
        return round(obj, 9)
    if isinstance(obj, bytes):
        return obj.hex()
    if hasattr(obj, "item"):           # numpy scalars
        return _jsonable(obj.item())
    return str(obj)


def emit(name: str, payload: Dict[str, Any]) -> str:
    """Write ``BENCH_<name>.json`` at the repo root; returns the path."""
    path = os.path.join(_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(_jsonable(payload), f, indent=2, sort_keys=True)
        f.write("\n")
    return path
