"""Fused paged-decode vs per-slot decode, and int8 vs fp32 KV cache.

Drives three :class:`~repro.serving.batch.BatchEngine` configurations
over the same prompt/token feed at example scale:

* ``unfused`` — the per-slot fallback loop (one ``module.apply`` per
  session per token: M weight passes per decode step),
* ``fused``   — the batched paged-attention path (one weight pass per
  step, KV gathered from the shared page pool),
* ``int8``    — fused with the quantized pool (per-page per-kv-head
  scales, fp32 staging tail for the partial page).

Throughput is tokens per *simulated* second under the engine's roofline
cost model (max of compute time and weight+KV bandwidth time at
``PEER_FLOPS``/``PEER_BW``): decode at batch M is bandwidth-bound, so
charging the weight read once per batch instead of once per session is
the fused win and the simnet cost model prices exactly that.  Cache
bytes are the engine's actual resident pool/cache bytes.  Logit fidelity
is measured, not assumed: the int8 engine's final-step logits are
compared against the fp32 fused engine's on the same feed, with the
max deviation reported next to the gate bound.

``--kernel-smoke`` gates: fused ≥2× unfused tokens/s, int8 cache ≤0.55×
fp32 bytes, int8 max logit deviation ≤ LOGIT_DEV_BOUND.
"""

from __future__ import annotations

import sys
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

from repro.configs import get_config
from repro.core.simnet import Sim
from repro.models import ops_for
from repro.serving.batch import BatchEngine
from repro.serving.sharded import ShardModule

#: accepted max |logit_int8 - logit_fp32| at this scale.  Per-page int8
#: bounds each cached K/V element's error by page_absmax/254 (<1%-scale
#: relative); measured deviation after attention + 4 layers is ~0.03 on
#: unnormalized ~[-15, 15] logits here, and the gate pins it at ~8x that
#: so quantization drift shows up as a red CI, not silent decay.
LOGIT_DEV_BOUND = 0.25

N_SESSIONS = 8
PROMPT_LEN = 12
DECODE_STEPS = 48


def _build_engine(cfg, params, sim: Sim, **kw) -> BatchEngine:
    module = ShardModule(cfg, params, (0, cfg.n_layers),
                        is_first=True, is_last=True)
    return BatchEngine(module, sim, n_slots=N_SESSIONS, page_size=8, **kw)


def _drive(eng: BatchEngine, sim: Sim, feed: List[np.ndarray] = None,
           ) -> Tuple[float, float, float, np.ndarray, List[np.ndarray]]:
    """Open N sessions and decode.  Without ``feed``, tokens are the
    engine's own greedy argmax; with ``feed`` (a recorded run's per-step
    token batches), the exact same tokens are replayed so two engines'
    logits differ only by their cache numerics.  Returns
    (decode_cost_s, tokens, cache_bytes, last_logits, fed_tokens)."""
    rng = np.random.default_rng(11)
    sessions = [f"s{i}" for i in range(N_SESSIONS)]
    prompts = rng.integers(1, 200, size=(N_SESSIONS, PROMPT_LEN))
    toks = {}
    for sid, prompt in zip(sessions, prompts):
        out, _ = sim.run_process(
            eng.open(sid, prompt[None].astype(np.int32),
                     PROMPT_LEN + DECODE_STEPS + 1))
        toks[sid] = int(np.argmax(out[0]))
    cost = 0.0
    tokens = 0
    last = None
    fed: List[np.ndarray] = []
    for t in range(DECODE_STEPS):
        x = (feed[t] if feed is not None
             else np.asarray([toks[s] for s in sessions], np.int32))
        fed.append(x)
        out, served, c = eng.step(sessions, x)
        cost += c
        tokens += len(served)
        for sid, row in zip(served, out):
            toks[sid] = int(np.argmax(row))
        last = out
    return cost, float(tokens), eng.kv_bytes(), np.asarray(last), fed


def main(report: List[str], smoke: bool = False) -> Dict[str, Any]:
    cfg = get_config("granite-8b").reduced(n_layers=4, d_model=64, vocab=256)
    params = ops_for(cfg).init(cfg, jax.random.PRNGKey(0))

    rows = {}
    logits = {}
    feed = None
    # the fp32 fused run goes first and records its greedy token feed;
    # the other engines replay it, so logit deltas are pure cache numerics
    for name, kw in (("fused", {}),
                     ("unfused", {"fused": False}),
                     ("int8", {"kv_dtype": "int8"})):
        sim = Sim(seed=3)
        eng = _build_engine(cfg, params, sim, **kw)
        cost, tokens, cache_bytes, last, fed = _drive(eng, sim, feed)
        if feed is None:
            feed = fed
        rows[name] = {"decode_cost_s": cost, "tokens": tokens,
                      "tokens_per_s": tokens / max(cost, 1e-12),
                      "cache_bytes": cache_bytes,
                      "fused": eng.fused, "kv_dtype": eng.kv_dtype}
        logits[name] = last

    speedup = rows["fused"]["tokens_per_s"] / rows["unfused"]["tokens_per_s"]
    byte_ratio = rows["int8"]["cache_bytes"] / rows["fused"]["cache_bytes"]
    same_path = np.array_equal(np.argmax(logits["int8"], axis=-1),
                               np.argmax(logits["fused"], axis=-1))
    logit_dev = float(np.abs(logits["int8"] - logits["fused"]).max())

    report.append(f"# Decode step: {N_SESSIONS} sessions, "
                  f"{PROMPT_LEN}-token prompts, {DECODE_STEPS} decode steps "
                  f"(granite-8b reduced: L=4 d=64)")
    report.append(f"{'engine':<10}{'tok/s':>12}{'cost_s':>12}"
                  f"{'cache_KiB':>12}")
    for name, r in rows.items():
        report.append(f"{name:<10}{r['tokens_per_s']:>12.0f}"
                      f"{r['decode_cost_s']:>12.2e}"
                      f"{r['cache_bytes'] / 1024:>12.1f}")
    report.append(f"fused speedup: {speedup:.2f}x   int8 cache: "
                  f"{byte_ratio:.2f}x fp32 bytes")
    report.append(f"int8 max logit deviation: {logit_dev:.4f} "
                  f"(bound {LOGIT_DEV_BOUND}, greedy path "
                  f"{'identical' if same_path else 'DIVERGED'})")

    metrics = {
        "engines": rows,
        "fused_speedup": speedup,
        "int8_cache_ratio": byte_ratio,
        "int8_max_logit_dev": logit_dev,
        "logit_dev_bound": LOGIT_DEV_BOUND,
        "greedy_path_identical": bool(same_path),
        "gates": {"fused_speedup_min": 2.0, "int8_cache_ratio_max": 0.55},
    }
    if smoke:
        ok = (speedup >= 2.0 and byte_ratio <= 0.55
              and logit_dev <= LOGIT_DEV_BOUND)
        report.append(f"smoke: {'OK' if ok else 'FAIL'}")
        if not ok:
            raise SystemExit(
                f"decode_step smoke failed: speedup={speedup:.2f} "
                f"(need >=2), int8_ratio={byte_ratio:.2f} (need <=0.55), "
                f"logit_dev={logit_dev:.4f} (need <={LOGIT_DEV_BOUND})")
    return metrics


if __name__ == "__main__":
    out: List[str] = []
    metrics = main(out, smoke="--kernel-smoke" in sys.argv)
    print("\n".join(out))
    try:
        from benchmarks import _bench
    except ImportError:         # standalone: benchmarks/ itself is on sys.path
        import _bench
    print(f"(wrote {_bench.emit('decode_step', metrics)})")
