"""Decentralized-CDN dissemination (paper Fig. 1-2/3): one training cluster
publishes a model version; N edge peers swarm-fetch it via DHT + Bitswap.
As fetchers complete they re-provide, so dissemination time grows
sub-linearly in fleet size."""

from __future__ import annotations

from typing import Generator, List

import numpy as np

from repro.core.fleet import make_fleet

ARTIFACT_MB = 8


def run_fleet(n_fetchers: int, stagger: float = 1.0) -> dict:
    fleet = make_fleet(n_fetchers + 1, seed=77, same_region="us")
    sim = fleet.sim
    seed_node = fleet.peers[0]
    # incompressible artifact: every 256 KiB chunk gets a distinct CID
    # (repetitive data dedups to one block and trivializes the benchmark)
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, ARTIFACT_MB * 2**20, dtype=np.uint8).tobytes()

    def publish() -> Generator:
        root = yield from seed_node.publish_artifact(data)
        return root

    root = sim.run_process(publish(), until=sim.now + 3600)
    t_start = sim.now
    times: List[float] = []

    def fetcher(node, delay: float) -> Generator:
        yield delay
        t0 = sim.now
        got = yield from node.fetch_artifact(root)
        assert got == data
        times.append(sim.now - t0)

    procs = [sim.process(fetcher(n, i * stagger))
             for i, n in enumerate(fleet.peers[1:])]
    sim.run_process(_wait_all(sim, procs), until=sim.now + 86400)
    served_by_seed = seed_node.bitswap.stats["bytes_served"]
    total_fetched = sum(n.bitswap.stats["bytes_fetched"]
                        for n in fleet.peers[1:])
    return {
        "n": n_fetchers,
        "makespan": sim.now - t_start,
        "mean_fetch": sum(times) / len(times),
        "seed_share": served_by_seed / max(total_fetched, 1),
    }


def _wait_all(sim, procs):
    yield sim.all_of(procs)


def main(report: List[str]) -> None:
    report.append(f"# Model dissemination ({ARTIFACT_MB} MiB artifact, "
                  "1 seed, swarm re-provides)")
    report.append(f"{'fetchers':>8} {'makespan_s':>10} {'mean_fetch_s':>12} "
                  f"{'seed_served_frac':>16}")
    for n in (2, 4, 8, 16):
        r = run_fleet(n)
        report.append(f"{r['n']:>8} {r['makespan']:>10.2f} "
                      f"{r['mean_fetch']:>12.2f} {r['seed_share']:>16.2f}")


if __name__ == "__main__":
    out: List[str] = []
    main(out)
    print("\n".join(out))
