"""Decentralized-CDN dissemination (paper Fig. 1-2/3): one training cluster
publishes a model version; N edge peers swarm-fetch it via DHT + Bitswap.
As fetchers complete they re-provide, so dissemination time grows
sub-linearly in fleet size.

The ``delta`` scenario exercises the hierarchical content plane: K
successive versions of a per-tensor (v2-manifest) checkpoint, each mutating
p% of the tensors.  Version N+1 fetchers should move bytes roughly
proportional to p, not to the checkpoint size — the structural-sharing
payoff that makes WAN model sync affordable.

The ``shifted`` scenario exercises content-defined chunking: version 2
*inserts* bytes near the front of a large part (a grown vocabulary, appended
optimizer state).  Under fixed-size chunking every downstream boundary
shifts and essentially no leaf block is reused; under a ``cdc`` ChunkSpec
boundaries re-synchronize right after the edit and the unchanged tail keeps
its leaf CIDs.

The ``quant`` scenario stacks block-quantized transfer on top of delta
reuse: the same churned-checkpoint sequence published fp32 vs
``int8_block`` (per-block scale+zero-point; the publisher's fp32 master
stays local) — followers should move ~4× fewer bytes *on the churned
tensors*, multiplying with the delta savings.

    PYTHONPATH=src python benchmarks/model_sync.py                # all
    PYTHONPATH=src python benchmarks/model_sync.py --delta-smoke  # CI gate
    PYTHONPATH=src python benchmarks/model_sync.py --cdc-smoke    # CI gate
    PYTHONPATH=src python benchmarks/model_sync.py --quant-smoke  # CI gate
"""

from __future__ import annotations

import sys
import time
from typing import Any, Dict, Generator, List

import numpy as np

from repro.core.cid import CODEC_RAW, ChunkSpec, cdc_cut_points, dag_reachable
from repro.core.fleet import make_fleet

ARTIFACT_MB = 8


def run_fleet(n_fetchers: int, stagger: float = 1.0) -> dict:
    fleet = make_fleet(n_fetchers + 1, seed=77, same_region="us")
    sim = fleet.sim
    seed_node = fleet.peers[0]
    # incompressible artifact: every 256 KiB chunk gets a distinct CID
    # (repetitive data dedups to one block and trivializes the benchmark)
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, ARTIFACT_MB * 2**20, dtype=np.uint8).tobytes()

    def publish() -> Generator:
        root = yield from seed_node.publish_artifact(data)
        return root

    root = sim.run_process(publish(), until=sim.now + 3600)
    t_start = sim.now
    times: List[float] = []

    def fetcher(node, delay: float) -> Generator:
        yield delay
        t0 = sim.now
        got = yield from node.fetch_artifact(root)
        assert got == data
        times.append(sim.now - t0)

    procs = [sim.process(fetcher(n, i * stagger))
             for i, n in enumerate(fleet.peers[1:])]
    sim.run_process(_wait_all(sim, procs), until=sim.now + 86400)
    served_by_seed = seed_node.bitswap.stats["bytes_served"]
    total_fetched = sum(n.bitswap.stats["bytes_fetched"]
                        for n in fleet.peers[1:])
    return {
        "n": n_fetchers,
        "makespan": sim.now - t_start,
        "mean_fetch": sum(times) / len(times),
        "seed_share": served_by_seed / max(total_fetched, 1),
    }


def _wait_all(sim, procs):
    yield sim.all_of(procs)


def run_delta(n_versions: int = 4, mutate_frac: float = 0.1,
              n_tensors: int = 20, tensor_kb: int = 384,
              n_fetchers: int = 2) -> List[dict]:
    """Publish K versions of a per-tensor checkpoint, mutating
    ``mutate_frac`` of the tensors each step; fetchers follow every version.
    Returns per-version rows with bytes-fetched and reuse fraction."""
    fleet = make_fleet(n_fetchers + 1, seed=91, same_region="us")
    sim = fleet.sim
    seed_node = fleet.peers[0]
    fetchers = fleet.peers[1:]
    rng = np.random.default_rng(17)

    def tensor(i: int, version: int) -> bytes:
        # content is a pure function of (tensor, last-mutated-version)
        return np.random.default_rng(1000 * i + version).integers(
            0, 256, tensor_kb * 1024, dtype=np.uint8).tobytes()

    versions = {i: 0 for i in range(n_tensors)}
    n_mutate = max(1, int(round(mutate_frac * n_tensors)))
    rows: List[dict] = []
    for v in range(n_versions):
        if v > 0:
            for i in rng.choice(n_tensors, size=n_mutate, replace=False):
                versions[int(i)] = v
        parts = [(f"t{i}", tensor(i, versions[i]), b"")
                 for i in range(n_tensors)]

        def publish(parts=parts):
            root = yield from seed_node.publish_tree_artifact(parts)
            return root

        root = sim.run_process(publish(), until=sim.now + 3600)
        t0 = sim.now
        before = [f.bitswap.stats["bytes_fetched"] for f in fetchers]

        def fetch(node) -> Generator:
            got = yield from node.fetch_artifact(root, reprovide=False)
            assert got == b"".join(p[1] for p in parts)
            node.pin_latest("delta-bench", root)

        procs = [sim.process(fetch(f)) for f in fetchers]
        sim.run_process(_wait_all(sim, procs), until=sim.now + 86400)
        fetched = [f.bitswap.stats["bytes_fetched"] - b0
                   for f, b0 in zip(fetchers, before)]
        total = n_tensors * tensor_kb * 1024
        rows.append({
            "version": v,
            "mutated": 0 if v == 0 else n_mutate,
            "mean_bytes_fetched": sum(fetched) / len(fetched),
            "full_bytes": total,
            "reuse_frac": 1.0 - (sum(fetched) / len(fetched)) / total,
            "makespan": sim.now - t0,
        })
    return rows


def run_shifted(strategy: str, part_mb: int = 2, edit_at: int = 4096,
                grow: int = 1536) -> dict:
    """Publish v1 of a checkpoint-shaped artifact, then v2 with ``grow``
    bytes inserted at offset ``edit_at`` of the big part (everything after
    the edit shifts).  Returns leaf-level byte reuse between the two DAGs
    plus what a follower actually moved over the mesh."""
    spec = (ChunkSpec.cdc(avg_size=64 * 1024) if strategy == "cdc"
            else ChunkSpec(strategy="fixed", chunk_size=64 * 1024))
    fleet = make_fleet(3, seed=101, same_region="us")
    sim = fleet.sim
    seed_node, fetcher = fleet.peers[0], fleet.peers[1]
    rng = np.random.default_rng(55)
    vocab = rng.integers(0, 256, part_mb * 2**20, dtype=np.uint8).tobytes()
    head = rng.integers(0, 256, 128 * 1024, dtype=np.uint8).tobytes()
    vocab2 = (vocab[:edit_at]
              + rng.integers(0, 256, grow, dtype=np.uint8).tobytes()
              + vocab[edit_at:])
    parts1 = [("head", head, b""), ("vocab", vocab, b"")]
    parts2 = [("head", head, b""), ("vocab", vocab2, b"")]

    def publish(parts):
        root = yield from seed_node.publish_tree_artifact(parts, spec=spec)
        return root

    def leaf_bytes(root) -> dict:
        peek = seed_node.blockstore.peek
        return {c: len(peek(c)) for c in dag_reachable(root, peek)
                if c.codec == CODEC_RAW and peek(c) is not None}

    def fetch(root, parts):
        got = yield from fetcher.fetch_artifact(root, reprovide=False)
        assert got == b"".join(p[1] for p in parts)
        fetcher.pin_latest("shift-bench", root)

    r1 = sim.run_process(publish(parts1), until=sim.now + 3600)
    sim.run_process(fetch(r1, parts1), until=sim.now + 86400)
    before = fetcher.bitswap.stats["bytes_fetched"]
    r2 = sim.run_process(publish(parts2), until=sim.now + 3600)
    sim.run_process(fetch(r2, parts2), until=sim.now + 86400)
    l1, l2 = leaf_bytes(r1), leaf_bytes(r2)
    total2 = sum(l2.values())
    reused = sum(size for c, size in l2.items() if c in l1)
    return {
        "strategy": strategy,
        "leaf_reuse": reused / total2,
        "n_leaves": len(l2),
        "full_bytes": total2,
        "fetched_bytes": fetcher.bitswap.stats["bytes_fetched"] - before,
    }


def run_quant(n_versions: int = 3, mutate_frac: float = 0.1,
              n_tensors: int = 20, tensor_kb: int = 384,
              n_fetchers: int = 2) -> Dict[str, Any]:
    """The delta scenario published twice — raw fp32 parts vs
    ``int8_block``-quantized parts — with identical churn.  Returns the
    per-mode mean delta-version fetch bytes and their ratio (the gate:
    quant sync should move ≤0.3× the fp32 bytes at 10% churn)."""
    from repro.checkpoint.serial import params_to_parts

    n_elems = tensor_kb * 1024 // 4

    def tensor(i: int, version: int) -> np.ndarray:
        return np.random.default_rng(2000 * i + version).normal(
            size=n_elems).astype(np.float32)

    out: Dict[str, Any] = {}
    for label, mode in (("fp32", None), ("int8_block", "int8_block")):
        fleet = make_fleet(n_fetchers + 1, seed=93, same_region="us")
        sim = fleet.sim
        seed_node = fleet.peers[0]
        fetchers = fleet.peers[1:]
        rng = np.random.default_rng(19)      # same churn in both modes
        versions = {i: 0 for i in range(n_tensors)}
        n_mutate = max(1, int(round(mutate_frac * n_tensors)))
        delta_fetched: List[float] = []
        for v in range(n_versions):
            if v > 0:
                for i in rng.choice(n_tensors, size=n_mutate, replace=False):
                    versions[int(i)] = v
            tree = {f"t{i:02d}": tensor(i, versions[i])
                    for i in range(n_tensors)}
            parts = params_to_parts(tree, quant=mode)

            def publish(parts=parts):
                root = yield from seed_node.publish_tree_artifact(parts)
                return root

            root = sim.run_process(publish(), until=sim.now + 3600)
            before = [f.bitswap.stats["bytes_fetched"] for f in fetchers]

            def fetch(node) -> Generator:
                yield from node.fetch_artifact(root, reprovide=False,
                                               assemble=False)
                node.pin_latest("quant-bench", root)

            procs = [sim.process(fetch(f)) for f in fetchers]
            sim.run_process(_wait_all(sim, procs), until=sim.now + 86400)
            fetched = [f.bitswap.stats["bytes_fetched"] - b0
                       for f, b0 in zip(fetchers, before)]
            if v > 0:
                delta_fetched.append(sum(fetched) / len(fetched))
        out[label] = {
            "mean_delta_bytes": sum(delta_fetched) / len(delta_fetched),
            "payload_bytes": sum(len(p[1]) for p in parts),
        }
    out["ratio"] = (out["int8_block"]["mean_delta_bytes"]
                    / out["fp32"]["mean_delta_bytes"])
    out["churn"] = mutate_frac
    return out


def run_codec() -> Dict[str, Any]:
    """Hot-path receipts: flat-blob serialize throughput (raw and
    quantized) and the vectorized gear-scan throughput (plain and
    normalized masks), plus the chunk-size spread tightening that the
    normalized masks buy."""
    from repro.checkpoint.serial import params_to_bytes

    rng = np.random.default_rng(3)
    tree = {f"w{i:02d}": rng.normal(size=(256, 1024)).astype(np.float32)
            for i in range(16)}                              # 16 MiB
    tree_mb = sum(a.nbytes for a in tree.values()) / 2**20

    def timed(fn, *args) -> float:
        fn(*args)                       # warm caches
        t0 = time.perf_counter()
        fn(*args)
        return time.perf_counter() - t0

    t_raw = timed(params_to_bytes, tree)
    t_quant = timed(params_to_bytes, tree, "int8_block")
    data = rng.integers(0, 256, ARTIFACT_MB * 2**20,
                        dtype=np.uint8).tobytes()
    mn, avg, mx = 16 * 1024, 64 * 1024, 256 * 1024
    t_scan = timed(cdc_cut_points, data, mn, avg, mx)
    t_scan_norm = timed(cdc_cut_points, data, mn, avg, mx, 2)

    def spread(norm: int) -> Dict[str, float]:
        sizes = np.diff([0] + cdc_cut_points(data, mn, avg, mx, norm))
        return {"n_chunks": int(len(sizes)),
                "mean": float(sizes.mean()),
                "cv": float(sizes.std() / sizes.mean())}

    return {
        "serialize_MBps": tree_mb / t_raw,
        "serialize_int8_block_MBps": tree_mb / t_quant,
        "cdc_scan_MBps": ARTIFACT_MB / t_scan,
        "cdc_scan_norm2_MBps": ARTIFACT_MB / t_scan_norm,
        "chunk_sizes_norm0": spread(0),
        "chunk_sizes_norm2": spread(2),
    }


def main(report: List[str]) -> Dict[str, Any]:
    report.append(f"# Model dissemination ({ARTIFACT_MB} MiB artifact, "
                  "1 seed, swarm re-provides)")
    report.append(f"{'fetchers':>8} {'makespan_s':>10} {'mean_fetch_s':>12} "
                  f"{'seed_served_frac':>16}")
    rows = []
    for n in (2, 4, 8, 16):
        r = run_fleet(n)
        rows.append(r)
        report.append(f"{r['n']:>8} {r['makespan']:>10.2f} "
                      f"{r['mean_fetch']:>12.2f} {r['seed_share']:>16.2f}")
    return {"fleet": rows}


def main_delta(report: List[str]) -> Dict[str, Any]:
    report.append("# Delta sync (per-tensor v2 manifests, 20 tensors, "
                  "10% mutated per version)")
    report.append(f"{'version':>7} {'mutated':>7} {'fetched_MiB':>11} "
                  f"{'full_MiB':>8} {'reuse':>6} {'makespan_s':>10}")
    rows = run_delta()
    for r in rows:
        report.append(
            f"{r['version']:>7} {r['mutated']:>7} "
            f"{r['mean_bytes_fetched'] / 2**20:>11.2f} "
            f"{r['full_bytes'] / 2**20:>8.2f} {r['reuse_frac']:>6.2f} "
            f"{r['makespan']:>10.2f}")
    return {"versions": rows}


def main_shifted(report: List[str]) -> Dict[str, Any]:
    report.append("# Shifted-edit delta (1.5 KiB inserted at 4 KiB of a "
                  "2 MiB part; 64 KiB chunks)")
    report.append(f"{'strategy':>8} {'leaves':>6} {'leaf_reuse':>10} "
                  f"{'fetched_KiB':>11} {'full_KiB':>8}")
    rows = []
    for strategy in ("fixed", "cdc"):
        r = run_shifted(strategy)
        rows.append(r)
        report.append(f"{r['strategy']:>8} {r['n_leaves']:>6} "
                      f"{r['leaf_reuse']:>10.2%} "
                      f"{r['fetched_bytes'] / 1024:>11.0f} "
                      f"{r['full_bytes'] / 1024:>8.0f}")
    return {"strategies": rows}


def main_quant(report: List[str]) -> Dict[str, Any]:
    q = run_quant()
    codec = run_codec()
    report.append("# Quantized sync (identical 10% churn, fp32 vs "
                  "int8_block parts)")
    report.append(
        f"delta fetch: fp32={q['fp32']['mean_delta_bytes'] / 2**20:.2f} MiB "
        f"int8_block={q['int8_block']['mean_delta_bytes'] / 2**20:.2f} MiB "
        f"ratio={q['ratio']:.2f} (gate <=0.30)")
    report.append(
        f"codec: serialize {codec['serialize_MBps']:.0f} MB/s "
        f"(int8_block {codec['serialize_int8_block_MBps']:.0f} MB/s), "
        f"cdc scan {codec['cdc_scan_MBps']:.0f} MB/s "
        f"(norm2 {codec['cdc_scan_norm2_MBps']:.0f} MB/s)")
    report.append(
        f"chunk-size CV: norm0={codec['chunk_sizes_norm0']['cv']:.2f} "
        f"norm2={codec['chunk_sizes_norm2']['cv']:.2f}")
    return {"quant": q, "codec": codec}


def cdc_smoke() -> None:
    """CI gate: a byte-shifting edit must keep >= 60% leaf-byte reuse under
    CDC while fixed-size chunking stays < 10% (acceptance criterion)."""
    cdc = run_shifted("cdc")
    fixed = run_shifted("fixed")
    assert cdc["leaf_reuse"] >= 0.60, (
        f"cdc regression: shifted edit reused only {cdc['leaf_reuse']:.0%} "
        "of leaf bytes (gate: >=60%)")
    assert fixed["leaf_reuse"] < 0.10, (
        f"fixed-chunk baseline unexpectedly reused {fixed['leaf_reuse']:.0%} "
        "of leaf bytes after a shifted edit — the scenario no longer shifts "
        "boundaries and the CDC gate proves nothing")
    print(f"cdc smoke ok: leaf reuse cdc={cdc['leaf_reuse']:.1%} vs "
          f"fixed={fixed['leaf_reuse']:.1%} after a shifted edit "
          "(gates: >=60% / <10%)")


def delta_smoke() -> None:
    """CI gate: with 10% of tensors mutated, every follow-up version must
    fetch < 30% of a full checkpoint (acceptance criterion)."""
    rows = run_delta(n_versions=3)
    for r in rows[1:]:
        frac = r["mean_bytes_fetched"] / r["full_bytes"]
        assert frac < 0.30, (
            f"delta regression: version {r['version']} fetched "
            f"{frac:.0%} of a full checkpoint (gate: <30%)")
    print("delta smoke ok: " + ", ".join(
        f"v{r['version']}={r['mean_bytes_fetched'] / r['full_bytes']:.1%}"
        for r in rows[1:]) + " of full fetch (gate <30%)")


def quant_smoke() -> None:
    """CI gate: int8_block sync must move <= 0.3x the fp32 bytes under
    identical 10% churn (acceptance criterion)."""
    q = run_quant()
    assert q["ratio"] <= 0.30, (
        f"quant regression: int8_block delta sync moved {q['ratio']:.2f}x "
        "the fp32 bytes at 10% churn (gate: <=0.30)")
    print(f"quant smoke ok: int8_block delta sync moved {q['ratio']:.2f}x "
          "the fp32 bytes at 10% churn (gate <=0.30)")


if __name__ == "__main__":
    if "--delta-smoke" in sys.argv:
        delta_smoke()
        sys.exit(0)
    if "--cdc-smoke" in sys.argv:
        cdc_smoke()
        sys.exit(0)
    if "--quant-smoke" in sys.argv:
        quant_smoke()
        sys.exit(0)
    out: List[str] = []
    main(out)
    main_delta(out)
    main_shifted(out)
    main_quant(out)
    print("\n".join(out))
