"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip roofline,...]

Sections:
    table1      RPC throughput (paper Table 1)
    nat         NAT traversal success rate (paper §4, ~70% direct)
    natmatrix   NAT-kind × NAT-kind punch matrix (DCUtR v2 predicted ports)
    dht         Kademlia lookup scaling (O(log N))
    cdn         model dissemination via Bitswap (Fig. 1-2/3)
    delta       per-tensor delta sync (v2 manifests, bytes ∝ churn)
    shifted     shifted-edit delta (CDC vs fixed chunk boundary stability)
    crdt        replicated-store convergence (anti-entropy vs delta push)
    crdtsync    v2 delta sync bytes vs full-state, push latency, v1 interop
    shards      sharded inference + failover (Fig. 1-4)
    serving     continuous batching: N concurrent clients, kill, pressure
    roofline    arch × shape roofline terms from the dry-run artifacts

Also emits a machine-readable ``name,us_per_call,derived`` CSV per section,
and — for any section that returns a metrics dict — ``BENCH_<name>.json``
at the repo root.
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Callable, List, Tuple

from . import (_bench, crdt_sync, dht_lookup, model_sync, nat_traversal,
               roofline, rpc_throughput, sharded_inference)

SECTIONS: List[Tuple[str, Callable[[List[str]], None]]] = [
    ("table1", rpc_throughput.main),
    ("nat", nat_traversal.main),
    ("natmatrix", nat_traversal.main_matrix),
    ("dht", dht_lookup.main),
    ("cdn", model_sync.main),
    ("delta", model_sync.main_delta),
    ("shifted", model_sync.main_shifted),
    ("crdt", crdt_sync.main),
    ("crdtsync", crdt_sync.main_sync),
    ("shards", sharded_inference.main),
    ("serving", sharded_inference.main_serving),
    ("roofline", roofline.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip", default="", help="comma-separated sections")
    ap.add_argument("--only", default="", help="comma-separated sections")
    args = ap.parse_args()
    skip = set(filter(None, args.skip.split(",")))
    only = set(filter(None, args.only.split(",")))

    csv_lines = ["name,us_per_call,derived"]
    for name, fn in SECTIONS:
        if name in skip or (only and name not in only):
            continue
        report: List[str] = []
        t0 = time.time()
        try:
            metrics = fn(report)
            status = "ok"
            if isinstance(metrics, dict):
                path = _bench.emit(name, metrics)
                report.append(f"(wrote {path})")
        except Exception as e:  # noqa: BLE001 — keep the harness going
            report.append(f"!! section {name} failed: {e!r}")
            status = "fail"
        dt = time.time() - t0
        print(f"\n===== [{name}] ({dt:.1f}s wall) =====")
        print("\n".join(report))
        csv_lines.append(f"{name},{dt * 1e6:.0f},{status}")
    print("\n===== CSV =====")
    print("\n".join(csv_lines))


if __name__ == "__main__":
    main()
