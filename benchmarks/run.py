"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip roofline,...]
    PYTHONPATH=src python -m benchmarks.run --smoke
    PYTHONPATH=src python -m benchmarks.run --require-bench

Sections:
    table1      RPC throughput (paper Table 1)
    nat         NAT traversal success rate (paper §4, ~70% direct)
    natmatrix   NAT-kind × NAT-kind punch matrix (DCUtR v2 predicted ports)
    dht         Kademlia lookup scaling (O(log N))
    cdn         model dissemination via Bitswap (Fig. 1-2/3)
    delta       per-tensor delta sync (v2 manifests, bytes ∝ churn)
    shifted     shifted-edit delta (CDC vs fixed chunk boundary stability)
    quant       int8_block wire quantization: sync bytes + codec throughput
    crdt        replicated-store convergence (anti-entropy vs delta push)
    crdtsync    v2 delta sync bytes vs full-state, push latency, v1 interop
    mstsync     MST probe bytes vs flat summary at 10k keys under churn
    fleet1k     1k-node fleet under churn: push delivery, DHT, registry
    fleet10k    10k-node fleet (DHT + registry anti-entropy planes)
    shards      sharded inference + failover (Fig. 1-4)
    serving     continuous batching: N concurrent clients, kill, pressure
    collab      DiLoCo-style collaborative rounds: loss vs baseline, bytes
    roofline    kernels executed + arch × shape roofline terms
    decodestep  fused paged-decode vs per-slot loop, int8 vs fp32 KV cache

Every section returns a metrics dict.  Sections are grouped into BENCH
artifacts (several sections can share one file, keyed by section name);
the orchestrator writes ``BENCH_<group>.json`` at the repo root for each
group that ran.  ``--smoke`` forwards ``smoke=True`` to sections that
accept it; ``--require-bench`` skips running anything and just verifies
that every expected ``BENCH_*.json`` artifact exists (exit 1 listing the
missing ones) — the CI receipts gate.
"""

from __future__ import annotations

import argparse
import inspect
import os
import sys
import time
from typing import Callable, Dict, List, Tuple

from . import (_bench, collab_train, crdt_sync, decode_step, dht_lookup,
               fleet_scale, model_sync, nat_traversal, roofline,
               rpc_throughput, sharded_inference)

#: section -> (BENCH group, runner).  Groups with ONE section emit the
#: section's dict directly (standalone scripts write the same shape);
#: multi-section groups emit {section_name: dict, ...}.
SECTIONS: List[Tuple[str, str, Callable[..., dict]]] = [
    ("table1", "rpc_throughput", rpc_throughput.main),
    ("nat", "nat_traversal", nat_traversal.main),
    ("natmatrix", "nat_traversal", nat_traversal.main_matrix),
    ("dht", "dht_lookup", dht_lookup.main),
    ("cdn", "model_sync", model_sync.main),
    ("delta", "model_sync", model_sync.main_delta),
    ("shifted", "model_sync", model_sync.main_shifted),
    ("quant", "model_sync", model_sync.main_quant),
    ("crdt", "crdt_sync", crdt_sync.main),
    ("crdtsync", "crdt_sync", crdt_sync.main_sync),
    ("mstsync", "crdt_sync", crdt_sync.main_mst),
    ("fleet1k", "fleet", fleet_scale.main_1k),
    ("fleet10k", "fleet", fleet_scale.main_10k),
    ("shards", "sharded", sharded_inference.main),
    ("serving", "serving", sharded_inference.main_serving),
    ("collab", "collab_train", collab_train.main),
    ("roofline", "roofline", roofline.main),
    ("decodestep", "decode_step", decode_step.main),
]

#: artifacts the --require-bench receipts gate demands at the repo root
REQUIRED_BENCH = sorted({group for _, group, _ in SECTIONS})

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def require_bench() -> int:
    """Verify every expected BENCH artifact exists; list what's missing."""
    missing = [g for g in REQUIRED_BENCH
               if not os.path.exists(os.path.join(_ROOT, f"BENCH_{g}.json"))]
    if missing:
        print("missing benchmark receipts: "
              + ", ".join(f"BENCH_{g}.json" for g in missing))
        print("run `PYTHONPATH=src python -m benchmarks.run` to regenerate")
        return 1
    print(f"all {len(REQUIRED_BENCH)} BENCH_*.json receipts present")
    return 0


def _call(fn: Callable[..., dict], report: List[str], smoke: bool) -> dict:
    if smoke and "smoke" in inspect.signature(fn).parameters:
        return fn(report, smoke=True)
    return fn(report)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip", default="", help="comma-separated sections")
    ap.add_argument("--only", default="", help="comma-separated sections")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scales where sections support it")
    ap.add_argument("--require-bench", action="store_true",
                    help="don't run anything; fail if any BENCH_*.json "
                         "receipt is missing")
    args = ap.parse_args()
    if args.require_bench:
        sys.exit(require_bench())
    skip = set(filter(None, args.skip.split(",")))
    only = set(filter(None, args.only.split(",")))

    csv_lines = ["name,us_per_call,derived"]
    groups: Dict[str, Dict[str, dict]] = {}
    for name, group, fn in SECTIONS:
        if name in skip or (only and name not in only):
            continue
        report: List[str] = []
        t0 = time.time()
        try:
            metrics = _call(fn, report, args.smoke)
            status = "ok"
            if isinstance(metrics, dict):
                groups.setdefault(group, {})[name] = metrics
        except Exception as e:  # noqa: BLE001 — keep the harness going
            report.append(f"!! section {name} failed: {e!r}")
            status = "fail"
        dt = time.time() - t0
        print(f"\n===== [{name}] ({dt:.1f}s wall) =====")
        print("\n".join(report))
        csv_lines.append(f"{name},{dt * 1e6:.0f},{status}")

    n_group_sections = {g: sum(1 for _, grp, _ in SECTIONS if grp == g)
                        for g in groups}
    for group, sections in groups.items():
        payload = (next(iter(sections.values()))
                   if n_group_sections[group] == 1 else sections)
        print(f"(wrote {_bench.emit(group, payload)})")
    print("\n===== CSV =====")
    print("\n".join(csv_lines))


if __name__ == "__main__":
    main()
