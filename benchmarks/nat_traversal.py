"""Paper §4: NAT traversal success — ~70% of attempts connect directly,
the rest fall back to circuit relays; ALL attempts connect some way.

Two views:

* ``main``        random pairs over a mixed-NAT fleet (the paper's headline
                  direct-connectivity number), with per-NAT-kind breakdown
* ``main_matrix`` the full NAT-kind × NAT-kind punch matrix over one mixed
                  fleet (public + 4 NAT kinds, symmetric split into
                  predictable/random allocators)

``--punch-smoke`` gates CI:
  1. mixed fleet (incl. symmetric peers) reaches >= 70% direct connectivity
     with relay fallback covering the rest (0 failed attempts);
  2. an all-cone fleet reaches >= 95% direct;
  3. PORT_RESTRICTED <-> SYMMETRIC(sequential) — the pair the seed's naive
     DCUtR always lost — succeeds via predicted-port punching.
"""

from __future__ import annotations

import argparse
import sys
from collections import defaultdict
from typing import Dict, Generator, List, Optional, Tuple

from repro.core.fleet import Fleet, make_fleet
from repro.core.nat import NATKind

N_PEERS = 30
N_ATTEMPTS = 200

#: All-cone composition for the >=95% gate (no symmetric boxes at all).
ALL_CONE_MIX = [
    (None, 0.10),
    (NATKind.FULL_CONE, 0.25),
    (NATKind.RESTRICTED_CONE, 0.30),
    (NATKind.PORT_RESTRICTED, 0.35),
]

#: Matrix fleet: two peers of each class, symmetric split by allocator.
MATRIX_SPECS = [
    ("public", None),
    ("full_cone", NATKind.FULL_CONE),
    ("restricted", NATKind.RESTRICTED_CONE),
    ("port_restricted", NATKind.PORT_RESTRICTED),
    ("sym/seq", (NATKind.SYMMETRIC, "sequential", 1)),
    ("sym/rand", (NATKind.SYMMETRIC, "random", 1)),
]


def _connect_outcome(fleet: Fleet, a, b) -> Optional[bool]:
    """True=direct, False=relayed, None=failed."""

    def connect() -> Generator:
        conn = yield from a.connect_info(b.info())
        return conn

    try:
        conn = fleet.sim.run_process(connect(), until=fleet.sim.now + 600)
    except Exception:
        return None
    return not conn.relayed


def run_pairs(fleet: Fleet, attempts: int) -> Dict[str, object]:
    sim = fleet.sim
    rng = sim.rng
    n = len(fleet.peers)
    counts = {"direct": 0, "relayed": 0, "failed": 0}
    by_pair: Dict[Tuple[str, str], List[int]] = defaultdict(lambda: [0, 0])
    for _ in range(attempts):
        i, j = rng.randrange(n), rng.randrange(n)
        if i == j:
            continue
        a, b = fleet.peers[i], fleet.peers[j]
        outcome = _connect_outcome(fleet, a, b)
        kinds = tuple(sorted((fleet.nat_kind_of(a), fleet.nat_kind_of(b))))
        by_pair[kinds][1] += 1
        if outcome is None:
            counts["failed"] += 1
        elif outcome:
            counts["direct"] += 1
            by_pair[kinds][0] += 1
        else:
            counts["relayed"] += 1
    counts["total"] = sum(counts.values())
    counts["by_pair"] = dict(by_pair)
    return counts


def _punch_totals(fleet: Fleet) -> Tuple[int, int, int]:
    ok = fail = predicted = 0
    for n in fleet.all_nodes:
        ok += n.transport.stats["punch_ok"]
        fail += n.transport.stats["punch_fail"]
        predicted += n.transport.stats["predicted_punch_ok"]
    return ok, fail, predicted


def main(report: List[str]) -> Dict[str, object]:
    fleet = make_fleet(N_PEERS, seed=123, maintenance=True)
    counts = run_pairs(fleet, N_ATTEMPTS)
    total = counts["total"]
    direct, relayed, failed = counts["direct"], counts["relayed"], counts["failed"]
    punch_ok, punch_fail, predicted = _punch_totals(fleet)
    report.append("# NAT traversal (paper: ~70% direct, rest via relay)")
    report.append(f"attempts={total} direct={direct} ({100*direct/total:.0f}%) "
                  f"relayed={relayed} ({100*relayed/total:.0f}%) "
                  f"failed={failed}")
    report.append(f"dcutr punches: ok={punch_ok} fail={punch_fail} "
                  f"({100*punch_ok/max(punch_ok+punch_fail,1):.0f}% punch rate), "
                  f"predicted-port punches={predicted}")
    hard = [(pair, d, t) for pair, (d, t) in sorted(counts["by_pair"].items())
            if any("symmetric" in k for k in pair)]
    if hard:
        report.append("symmetric-involved pairs (direct/attempts):")
        for pair, d, t in hard:
            report.append(f"  {pair[0]:28s} x {pair[1]:28s} {d}/{t}")
    report.append("per-NAT-kind box stats (mappings / inbound ok / filtered):")
    for kind, row in sorted(fleet.net.nat_stats().items()):
        report.append(f"  {kind:24s} boxes={row['boxes']:2d} "
                      f"map={row['mappings']:5d} ok={row['inbound_ok']:5d} "
                      f"filt={row['inbound_filtered']:5d}")
    return {"attempts": total, "direct": direct, "relayed": relayed,
            "failed": failed, "direct_rate": direct / max(total, 1),
            "punch_ok": punch_ok, "punch_fail": punch_fail,
            "predicted_punch_ok": predicted,
            "symmetric_pairs": [
                {"pair": list(pair), "direct": d, "attempts": t}
                for pair, d, t in hard]}


def run_matrix(seed: int = 31) -> Dict[Tuple[str, str], Optional[bool]]:
    """Punch one pair per ordered NAT-kind combination over a mixed fleet."""
    labels = [lbl for lbl, _ in MATRIX_SPECS]
    specs = [spec for _, spec in MATRIX_SPECS]
    # two peers of each class so same-kind pairs exist
    fleet = make_fleet(2 * len(specs), seed=seed, nat_kinds=specs + specs,
                       maintenance=True)
    first = {lbl: fleet.peers[i] for i, lbl in enumerate(labels)}
    second = {lbl: fleet.peers[len(labels) + i] for i, lbl in enumerate(labels)}
    grid: Dict[Tuple[str, str], Optional[bool]] = {}
    for la in labels:
        for lb in labels:
            # initiators come from the first replica, responders from the
            # second: every ordered cell gets a DISTINCT host pair, so the
            # reverse direction measures its own punch instead of reusing
            # the connection the forward cell already established
            grid[(la, lb)] = _connect_outcome(fleet, first[la], second[lb])
    return grid


def main_matrix(report: List[str]) -> Dict[str, object]:
    grid = run_matrix()
    labels = [lbl for lbl, _ in MATRIX_SPECS]
    report.append("# NAT-kind punch matrix (D=direct, r=relayed, X=failed)")
    width = max(len(l) for l in labels) + 1
    report.append(" " * width + " ".join(f"{l:>{width}}" for l in labels))
    for la in labels:
        cells = []
        for lb in labels:
            out = grid[(la, lb)]
            cells.append({True: "D", False: "r", None: "X"}[out])
        report.append(f"{la:>{width}} " +
                      " ".join(f"{c:>{width}}" for c in cells))
    n_direct = sum(1 for v in grid.values() if v is True)
    n_fail = sum(1 for v in grid.values() if v is None)
    report.append(f"direct cells: {n_direct}/{len(grid)}, failed: {n_fail}")
    outcome = {True: "direct", False: "relayed", None: "failed"}
    return {"labels": labels,
            "cells": [{"initiator": la, "responder": lb,
                       "outcome": outcome[grid[(la, lb)]]}
                      for la in labels for lb in labels],
            "direct_cells": n_direct, "failed_cells": n_fail,
            "total_cells": len(grid)}


def punch_smoke() -> int:
    failures: List[str] = []

    # gate 1: mixed fleet with symmetric peers present
    fleet = make_fleet(N_PEERS, seed=123, maintenance=True)
    kinds = {fleet.nat_kind_of(p) for p in fleet.peers}
    assert any(k.startswith("symmetric") for k in kinds), \
        "smoke fleet must include symmetric peers"
    counts = run_pairs(fleet, N_ATTEMPTS)
    rate = counts["direct"] / counts["total"]
    _, _, predicted = _punch_totals(fleet)
    print(f"[mixed]    direct={counts['direct']}/{counts['total']} "
          f"({100*rate:.0f}%) relayed={counts['relayed']} "
          f"failed={counts['failed']} predicted_punches={predicted}")
    if rate < 0.70:
        failures.append(f"mixed-fleet direct rate {100*rate:.0f}% < 70%")
    if counts["failed"]:
        failures.append(f"{counts['failed']} attempts had NO path "
                        "(relay fallback must cover punch failures)")

    # gate 2: all-cone fleet
    cone = make_fleet(20, seed=7, nat_mix=ALL_CONE_MIX, maintenance=True)
    ccounts = run_pairs(cone, 120)
    crate = ccounts["direct"] / ccounts["total"]
    print(f"[all-cone] direct={ccounts['direct']}/{ccounts['total']} "
          f"({100*crate:.0f}%) relayed={ccounts['relayed']} "
          f"failed={ccounts['failed']}")
    if crate < 0.95:
        failures.append(f"all-cone direct rate {100*crate:.0f}% < 95%")

    # gate 3: the seed-failing pair under port prediction
    grid = run_matrix()
    for pair in (("port_restricted", "sym/seq"), ("sym/seq", "port_restricted")):
        out = grid[pair]
        print(f"[matrix]   {pair[0]} -> {pair[1]}: "
              f"{ {True: 'direct', False: 'relayed', None: 'failed'}[out] }")
        if out is not True:
            failures.append(f"{pair[0]} -> {pair[1]} did not go direct "
                            "under predicted-port punching")
    if grid[("sym/rand", "sym/rand")] is None:
        failures.append("sym/rand pair lost connectivity entirely "
                        "(relay fallback broken)")

    for f in failures:
        print(f"FAIL: {f}")
    if not failures:
        print("punch smoke OK")
    return 1 if failures else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--punch-smoke", action="store_true",
                    help="gate: mixed >=70%% direct, all-cone >=95%%, "
                         "predicted-port pairs upgrade")
    ap.add_argument("--matrix", action="store_true",
                    help="print the NAT-kind punch matrix only")
    args = ap.parse_args()
    if args.punch_smoke:
        sys.exit(punch_smoke())
    out: List[str] = []
    if args.matrix:
        main_matrix(out)
    else:
        main(out)
        main_matrix(out)
    print("\n".join(out))
