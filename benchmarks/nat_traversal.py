"""Paper §4: NAT traversal success — ~70% of attempts connect directly,
the rest fall back to circuit relays; ALL attempts connect some way."""

from __future__ import annotations

from typing import Generator, List

from repro.core.fleet import make_fleet

N_PEERS = 30
N_ATTEMPTS = 200


def main(report: List[str]) -> None:
    fleet = make_fleet(N_PEERS, seed=123)
    sim = fleet.sim
    rng = sim.rng
    direct = relayed = failed = punch_ok = punch_fail = 0
    for _ in range(N_ATTEMPTS):
        i = rng.randrange(N_PEERS)
        j = rng.randrange(N_PEERS)
        if i == j:
            continue
        a, b = fleet.peers[i], fleet.peers[j]

        def connect(a=a, b=b) -> Generator:
            conn = yield from a.connect_info(b.info())
            return conn

        try:
            conn = sim.run_process(connect(), until=sim.now + 600)
        except Exception:
            failed += 1
            continue
        if conn.relayed:
            relayed += 1
        else:
            direct += 1
    for n in fleet.all_nodes:
        punch_ok += n.transport.stats["punch_ok"]
        punch_fail += n.transport.stats["punch_fail"]
    total = direct + relayed + failed
    report.append("# NAT traversal (paper: ~70% direct, rest via relay)")
    report.append(f"attempts={total} direct={direct} ({100*direct/total:.0f}%) "
                  f"relayed={relayed} ({100*relayed/total:.0f}%) "
                  f"failed={failed}")
    report.append(f"dcutr punches: ok={punch_ok} fail={punch_fail} "
                  f"({100*punch_ok/max(punch_ok+punch_fail,1):.0f}% punch rate)")


if __name__ == "__main__":
    out: List[str] = []
    main(out)
    print("\n".join(out))
