"""Roofline receipts: execute the Pallas kernels + analyze dry-run artifacts.

Two halves, both emitted into ``BENCH_roofline.json``:

* ``kernels`` — actually *runs* the seed kernels (flash_attention,
  moe_gating, mlstm_scan) plus the paged-decode attention path at small
  shapes on whatever backend is present (CPU CI executes the interpret /
  jnp fallbacks; a TPU runs compiled Mosaic), and records wall time next
  to the analytic FLOP/byte roofline terms.  Interpret-mode wall times
  are *not* device performance — they are regression receipts: the
  analytic ``compute_s``/``memory_s`` columns carry the roofline story,
  the measured times catch "the kernel got 10x slower" drift.

* ``dryrun`` — the (arch × shape) analysis of ``dryrun_single_pod.json``
  when that artifact exists:

    compute    = HLO_FLOPs_per_dev / peak_FLOP/s      (197 TF/s bf16, v5e)
    memory     = HLO_bytes_per_dev / HBM_bw           (819 GB/s)
    collective = collective_bytes_per_dev / link_bw   (50 GB/s/link)

  Caveat recorded per row: XLA's cost_analysis counts while-loop bodies
  ONCE (scan over layers / microbatches / chunks), so HLO_FLOPs is a lower
  bound; MODEL_FLOPS (6·N·D train, 2·N·D inference) is the analytic
  cross-check and the ratio column flags the undercount.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,
    "long_500k": 1,
}


# ======================================================================
# kernel execution
# ======================================================================

def _time_call(fn, *args, reps: int = 3) -> float:
    """Median wall seconds per call after a compile/warmup invocation."""
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _kernel_cases(smoke: bool) -> List[Dict[str, Any]]:
    from repro.kernels import (flash_attention, mlstm_scan, moe_gating,
                               paged_decode_attention)
    rng = np.random.default_rng(7)
    cases: List[Dict[str, Any]] = []

    B, S, H, hd = 1, 128 if smoke else 256, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    # causal: ~half the S*S score matrix does useful work
    flops = 2 * 2 * B * H * S * S * hd / 2
    bytes_ = 4 * (3 + 1) * B * S * H * hd
    cases.append({"name": "flash_attention",
                  "shape": f"B{B} S{S} H{H} hd{hd}",
                  "fn": flash_attention, "args": (q, k, v),
                  "flops": flops, "bytes": bytes_})

    T, E, topk = 512, 8, 2
    logits = jnp.asarray(rng.normal(size=(T, E)), jnp.float32)
    cases.append({"name": "moe_gating", "shape": f"T{T} E{E} k{topk}",
                  "fn": lambda l: moe_gating(l, topk), "args": (logits,),
                  "flops": 5 * T * E, "bytes": 4 * (T * E * 2 + T * topk * 2)})

    B, H, S, hd, chunk = 1, 2, 128, 32, 64
    qs = jnp.asarray(rng.normal(size=(B, H, S, hd)), jnp.float32)
    ks = jnp.asarray(rng.normal(size=(B, H, S, hd)), jnp.float32) / np.sqrt(hd)
    vs = jnp.asarray(rng.normal(size=(B, H, S, hd)), jnp.float32)
    li = jnp.asarray(rng.normal(size=(B, H, S)), jnp.float32)
    lf = jnp.zeros((B, H, S), jnp.float32)
    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    cases.append({"name": "mlstm_scan",
                  "shape": f"B{B} H{H} S{S} hd{hd} chunk{chunk}",
                  "fn": lambda *a: mlstm_scan(*a, chunk=chunk),
                  "args": (qs, ks, vs, li, lf, C0, n0, m0),
                  "flops": 2 * 2 * B * H * S * chunk * hd + 2 * B * H * S * hd * hd,
                  "bytes": 4 * B * H * S * hd * 3})

    M, page, NP, Hk, rep = 4, 16, 4, 2, 2
    Hq = Hk * rep
    kp = jnp.asarray(rng.normal(size=(NP * M, page, Hk, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NP * M, page, Hk, hd)), jnp.float32)
    bt = jnp.asarray(rng.permutation(NP * M).reshape(M, NP), jnp.int32)
    lengths = jnp.asarray([page * NP - 1, 17, 40, 9], jnp.int32)
    pq = jnp.asarray(rng.normal(size=(M, Hq, hd)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(M, Hk, hd)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(M, Hk, hd)), jnp.float32)
    T = NP * page
    cases.append({"name": "paged_decode_attention",
                  "shape": f"M{M} pages{NP}x{page} H{Hq} hd{hd}",
                  "fn": paged_decode_attention,
                  "args": (pq, kp, vp, bt, lengths, kn, vn),
                  "flops": 2 * 2 * M * Hq * T * hd,
                  "bytes": 4 * 2 * M * T * Hk * hd})
    return cases


def run_kernels(smoke: bool = False) -> List[Dict[str, Any]]:
    rows = []
    for case in _kernel_cases(smoke):
        wall = _time_call(case["fn"], *case["args"])
        compute = case["flops"] / PEAK_FLOPS
        memory = case["bytes"] / HBM_BW
        rows.append({
            "kernel": case["name"], "shape": case["shape"],
            "wall_ms": wall * 1e3,
            "flops": case["flops"], "bytes": case["bytes"],
            "compute_s": compute, "memory_s": memory,
            "dominant": "compute" if compute >= memory else "memory",
            "arith_intensity": case["flops"] / max(case["bytes"], 1),
        })
    return rows


# ======================================================================
# dry-run artifact analysis
# ======================================================================

def analyze_record(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if "error" in rec or "skipped" in rec:
        return None
    n_dev = rec.get("n_devices", 256)
    compute = rec["hlo_flops_per_dev"] / PEAK_FLOPS
    memory = rec["hlo_bytes_per_dev"] / HBM_BW
    collective = rec["collective_bytes_per_dev"] / LINK_BW
    tokens = SHAPE_TOKENS[rec["shape"]]
    n_active = rec["active_params"]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    model_flops_per_dev = mult * n_active * tokens / n_dev
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    total = max(sum(terms.values()), 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dominant,
        "dominant_frac": terms[dominant] / total,
        "model_flops_per_dev": model_flops_per_dev,
        "hlo_flops_per_dev": rec["hlo_flops_per_dev"],
        "flops_ratio": model_flops_per_dev / max(rec["hlo_flops_per_dev"], 1),
        "mem_gib_per_dev": (rec["bytes_args_per_dev"]
                            + rec["bytes_temp_per_dev"]
                            + rec["bytes_out_per_dev"]) / 2**30,
        "collectives": rec.get("collective_counts", {}),
    }


def suggest(row: Dict[str, Any]) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("reshard to cut the dominant collective (all-reduce -> "
                "reduce-scatter, or keep activations sharded through the "
                "boundary)")
    if d == "memory":
        return ("shrink the live set: smaller microbatch / tighter remat "
                "policy / keep caches sharded; check for f32 upcasts of "
                "bf16 stashes")
    return ("compute-bound: raise MXU utilization (128-aligned tiles, "
            "fused kernels) or shed redundant recompute")


def load(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        return json.load(f)


def main(report: List[str], path: str = "dryrun_single_pod.json",
         smoke: bool = False) -> Dict[str, Any]:
    backend = jax.default_backend()
    krows = run_kernels(smoke=smoke)
    report.append(f"# Roofline: kernels executed on backend={backend} "
                  "(CPU = interpret/jnp fallbacks; wall times are "
                  "regression receipts, analytic terms are the roofline)")
    report.append(f"{'kernel':<24}{'shape':<26}{'wall_ms':>9}"
                  f"{'compute':>10}{'memory':>10} {'dominant':<8}{'AI':>7}")
    for r in krows:
        report.append(
            f"{r['kernel']:<24}{r['shape']:<26}{r['wall_ms']:>9.2f}"
            f"{r['compute_s']:>10.2e}{r['memory_s']:>10.2e} "
            f"{r['dominant']:<8}{r['arith_intensity']:>7.1f}")

    rows: List[Dict[str, Any]] = []
    if os.path.exists(path):
        rows = [r for r in (analyze_record(x) for x in load(path)) if r]
        report.append("# Roofline terms per (arch × shape), single-pod "
                      "16×16 (seconds/step/device)")
        report.append(
            f"{'arch':<17}{'shape':<13}{'compute':>10}{'memory':>10}"
            f"{'collect':>10} {'dominant':<11}{'mem_GiB':>8}{'MF/HF':>7}")
        for r in rows:
            report.append(
                f"{r['arch']:<17}{r['shape']:<13}{r['compute_s']:>10.2e}"
                f"{r['memory_s']:>10.2e}{r['collective_s']:>10.2e} "
                f"{r['dominant']:<11}{r['mem_gib_per_dev']:>8.1f}"
                f"{r['flops_ratio']:>7.1f}")
    else:
        report.append(f"# dry-run artifact {path} missing — run "
                      f"`python -m repro.launch.dryrun --all --out {path}` "
                      "for the (arch × shape) table")
    return {"backend": backend, "kernels": krows, "dryrun": rows,
            "peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "link_bw": LINK_BW}


if __name__ == "__main__":
    out: List[str] = []
    metrics = main(out)
    print("\n".join(out))
    from benchmarks import _bench
    print(f"(wrote {_bench.emit('roofline', metrics)})")
