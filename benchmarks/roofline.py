"""Roofline terms per (arch × shape) from the dry-run artifacts.

    compute    = HLO_FLOPs_per_dev / peak_FLOP/s      (197 TF/s bf16, v5e)
    memory     = HLO_bytes_per_dev / HBM_bw           (819 GB/s)
    collective = collective_bytes_per_dev / link_bw   (50 GB/s/link)

Caveat recorded per row: XLA's cost_analysis counts while-loop bodies ONCE
(scan over layers / microbatches / chunks), so HLO_FLOPs is a lower bound;
MODEL_FLOPS (6·N·D train, 2·N·D inference, N=active params) is the analytic
cross-check and the ratio column flags the undercount (ratio >> 1 ==> deep
scan nesting; ratio << 1 ==> remat/redundant compute).
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,
    "long_500k": 1,
}


def analyze_record(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if "error" in rec or "skipped" in rec:
        return None
    n_dev = rec.get("n_devices", 256)
    compute = rec["hlo_flops_per_dev"] / PEAK_FLOPS
    memory = rec["hlo_bytes_per_dev"] / HBM_BW
    collective = rec["collective_bytes_per_dev"] / LINK_BW
    tokens = SHAPE_TOKENS[rec["shape"]]
    n_active = rec["active_params"]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    model_flops_per_dev = mult * n_active * tokens / n_dev
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    total = max(sum(terms.values()), 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dominant,
        "dominant_frac": terms[dominant] / total,
        "model_flops_per_dev": model_flops_per_dev,
        "hlo_flops_per_dev": rec["hlo_flops_per_dev"],
        "flops_ratio": model_flops_per_dev / max(rec["hlo_flops_per_dev"], 1),
        "mem_gib_per_dev": (rec["bytes_args_per_dev"]
                            + rec["bytes_temp_per_dev"]
                            + rec["bytes_out_per_dev"]) / 2**30,
        "collectives": rec.get("collective_counts", {}),
    }


def suggest(row: Dict[str, Any]) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("reshard to cut the dominant collective (all-reduce -> "
                "reduce-scatter, or keep activations sharded through the "
                "boundary)")
    if d == "memory":
        return ("shrink the live set: smaller microbatch / tighter remat "
                "policy / keep caches sharded; check for f32 upcasts of "
                "bf16 stashes")
    return ("compute-bound: raise MXU utilization (128-aligned tiles, "
            "fused kernels) or shed redundant recompute")


def load(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        return json.load(f)


def main(report: List[str],
         path: str = "dryrun_single_pod.json") -> List[Dict[str, Any]]:
    if not os.path.exists(path):
        report.append(f"# Roofline: {path} missing — run "
                      "`python -m repro.launch.dryrun --all --out {path}`")
        return []
    rows = [r for r in (analyze_record(x) for x in load(path)) if r]
    report.append("# Roofline terms per (arch × shape), single-pod 16×16 "
                  "(seconds/step/device)")
    report.append(
        f"{'arch':<17}{'shape':<13}{'compute':>10}{'memory':>10}"
        f"{'collect':>10} {'dominant':<11}{'mem_GiB':>8}{'MF/HF':>7}")
    for r in rows:
        report.append(
            f"{r['arch']:<17}{r['shape']:<13}{r['compute_s']:>10.2e}"
            f"{r['memory_s']:>10.2e}{r['collective_s']:>10.2e} "
            f"{r['dominant']:<11}{r['mem_gib_per_dev']:>8.1f}"
            f"{r['flops_ratio']:>7.1f}")
    return rows


if __name__ == "__main__":
    out: List[str] = []
    main(out)
    print("\n".join(out))
