"""Collaborative low-communication training (DiLoCo-style rounds over the
mesh): loss-vs-rounds against a single-node baseline at equal total steps,
bytes-per-round against a naive fp32 all-exchange, and convergence under a
mid-run churn wave that takes out live workers.

The fleet is a 2-region ``make_scale_fleet`` overlay (us/eu round-robin)
with the transcontinental ``inter`` link squeezed to ~100 Mbps — the
heterogeneous-bandwidth setting where one compressed pseudo-gradient
round per H inner steps is the difference between feasible and not.

    PYTHONPATH=src python benchmarks/collab_train.py                # report
    PYTHONPATH=src python benchmarks/collab_train.py --train-smoke  # CI gate

``--train-smoke`` gates (wired into scripts/ci.sh):
  * final outer eval loss within 5% of the single-node baseline run for
    the same total number of optimizer steps;
  * compressed wire bytes <= 0.10x the fp32 full-exchange bytes;
  * the churn wave kills >= 2 workers mid-round with ZERO aborted rounds,
    survivors close every round and stay bit-identical, and the killed
    workers rejoin onto the same digest via CRDT catch-up;
  * a reduced double-run under ``Sim(sanitize=True)`` produces identical
    event-trace digests and outer digests, with the contribution-pin
    leak gauge at zero.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Tuple

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import make_fleet, make_scale_fleet
from repro.core.nat import NATKind
from repro.core.simnet import Sim
from repro.data import make_batch_iterator
from repro.models import ops_for
from repro.optim import cosine_schedule
from repro.train import train_state_init
from repro.train.collab import CollabConfig, CollabWorker
from repro.train.step import make_train_step

try:
    from . import _bench
except ImportError:         # standalone: benchmarks/ itself is on sys.path
    import _bench

#: NAT mix for the training overlay: half the fleet public (training
#: workers want dialable contribution providers), the rest behind the
#: hard-NAT kinds churn waves restart
_TRAIN_NAT_MIX = [(None, 0.50), (NATKind.FULL_CONE, 0.15),
                  (NATKind.PORT_RESTRICTED, 0.20), (NATKind.SYMMETRIC, 0.15)]

_SEQ, _BATCH = 32, 8    # global batch: 1/worker sharded, whole on baseline


def _cfg():
    return get_config("minicpm-2b").reduced(n_layers=2, d_model=64, vocab=128)


def _eval_batch(cfg) -> Dict[str, np.ndarray]:
    """Held-out batch (its own stream seed) every loss number uses."""
    return next(make_batch_iterator(cfg.vocab, _SEQ, global_batch=8,
                                    seed=999))


def _baseline_curve(cfg, rounds: int, inner_steps: int,
                    eval_batch: Dict[str, np.ndarray]) -> List[float]:
    """Single-node run at equal total steps: same model, same schedule,
    the unsharded stream, eval after every H-step block."""
    ops = ops_for(cfg)
    sched = cosine_schedule(1e-3, 5, 400)
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, sched))
    eval_fn = jax.jit(lambda p, b: ops.loss_fn(p, cfg, b)[0])
    data = make_batch_iterator(cfg.vocab, _SEQ, global_batch=_BATCH,
                               n_shards=1, shard=0, seed=1)
    curve = []
    for _ in range(rounds):
        for _ in range(inner_steps):
            state, _ = step_fn(state, next(data))
        curve.append(float(eval_fn(state.params, eval_batch)))
    return curve


def _make_workers(nodes, cfg, ccfg, eval_batch,
                  fleet_name: str = "diloco") -> List[CollabWorker]:
    sched = cosine_schedule(1e-3, 5, 400)
    workers = []
    for i, node in enumerate(nodes):
        data = make_batch_iterator(cfg.vocab, _SEQ, global_batch=_BATCH,
                                   n_shards=len(nodes), shard=i, seed=1)
        workers.append(CollabWorker(
            node, cfg, train_state_init(cfg, jax.random.PRNGKey(0)),
            sched, data, fleet_name, collab=ccfg, step_seconds=0.2,
            eval_batch=eval_batch if i == 0 else None))
    return workers


def _pick_worker_nodes(fleet, n: int):
    """``n`` public hosts, regions interleaved — every round crosses the
    thin inter-region path."""
    by_region: Dict[str, List[Any]] = {}
    for node in fleet.publics:
        by_region.setdefault(node.host.region, []).append(node)
    order = sorted(by_region)
    picked: List[Any] = []
    i = 0
    while len(picked) < n:
        pool = by_region[order[i % len(order)]]
        if pool:
            picked.append(pool.pop(0))
        elif not any(by_region.values()):
            raise RuntimeError("not enough public nodes for the worker set")
        i += 1
    return picked


def _digest_probe(seed: int) -> Tuple[str, set, int, Dict[str, float]]:
    """Reduced double-run scenario under the sanitizer: returns the
    event-trace digest, the fleet's outer-digest set, overdue pins, and
    the leak audit."""
    cfg = _cfg()
    sim = Sim(seed=seed, sanitize=True)
    fleet = make_fleet(6, seed=seed, same_region="us", sim=sim)
    ccfg = CollabConfig(inner_steps=4, settle=0.5)
    workers = _make_workers([fleet.peers[i] for i in range(4)], cfg, ccfg,
                            eval_batch=None, fleet_name="sanfleet")
    sim.leak_baseline()
    procs = [sim.process(w.run(2, log=None)) for w in workers]
    sim.run(until=sim.now + 400)
    for p in procs:
        assert p.triggered and not p.failed, getattr(p, "value", None)
    overdue = sum(w.overdue_pins() for w in workers)
    return (sim.trace_digest(), {w.outer_digest() for w in workers},
            overdue, sim.leak_audit())


def main(report: List[str], smoke: bool = False) -> Dict[str, Any]:
    n_workers = 8
    rounds = 4 if smoke else 6
    inner_steps = 50
    cfg = _cfg()
    eval_batch = _eval_batch(cfg)

    # -- single-node baseline: equal total optimizer steps ------------------
    base_curve = _baseline_curve(cfg, rounds, inner_steps, eval_batch)

    # -- 2-region heterogeneous fleet: thin ~100 Mbps inter-region path -----
    fleet = make_scale_fleet(
        24, seed=5, nat_mix=_TRAIN_NAT_MIX, regions=["us", "eu"],
        latency={"inter": 60e-3}, bandwidth={"inter": 1.2e7})
    sim = fleet.sim
    # outer (lr, momentum) tuned for few-round convergence at this scale:
    # the DiLoCo defaults (0.7/0.9) need tens of rounds to settle, while
    # 0.4/0.6 is within 5% of the baseline by round 4
    ccfg = CollabConfig(inner_steps=inner_steps, settle=0.5, topk_frac=0.05,
                        outer_lr=0.4, outer_momentum=0.6, keep_rounds=3)
    worker_nodes = _pick_worker_nodes(fleet, n_workers)
    workers = _make_workers(worker_nodes, cfg, ccfg, eval_batch)
    procs = [sim.process(w.run(rounds, log=None)) for w in workers]

    # -- mid-run churn wave: restarts a slice of the NAT'd mesh AND takes
    # out two live worker hosts while round 1's inner phase is running
    doomed = workers[-2:]
    churned: List[Any] = []

    def churn() -> Generator:
        while not any(h["round"] == 1 for h in workers[0].history):
            yield 0.25
        yield 0.3
        churned.extend(fleet.churn_wave(0.25))
        for w in doomed:
            fleet._restart(w.node)
            w.stop()
            churned.append(w.node)

    sim.process(churn(), daemon=True)
    sim.run(until=sim.now + 3600)
    survivors = workers[:-2]
    for p, w in zip(procs, workers):
        if w in doomed:
            continue
        assert p.triggered, f"{w.name} never finished"
        assert not p.failed, p.value

    # -- killed workers rejoin: catch up from the CRDT record + pinned DAGs
    rejoin = [sim.process(w.run(0, log=None)) for w in doomed]
    sim.run(until=sim.now + 600)
    for p in rejoin:
        assert p.triggered and not p.failed, getattr(p, "value", None)

    digests = {w.outer_digest() for w in workers}
    aborted = sum(w.stats["rounds_aborted"] for w in workers)
    wire = sum(w.stats["wire_bytes"] for w in survivors)
    dense = sum(w.stats["dense_bytes"] for w in survivors)
    collab_curve = [rec["eval_loss"] for rec in workers[0].round_log]
    # bytes one round moves fleet-wide: every contributor ships its
    # compressed delta once vs the naive fp32 everyone-ships-dense exchange
    per_round_wire = wire / (len(survivors) * rounds)
    per_round_dense = dense / (len(survivors) * rounds)
    loss_gap = abs(collab_curve[-1] - base_curve[-1]) / base_curve[-1]

    # -- determinism: reduced double-run under the sanitizer ----------------
    d1 = _digest_probe(11)
    d2 = _digest_probe(11)

    metrics: Dict[str, Any] = {
        "smoke": smoke,
        "n_workers": n_workers,
        "rounds": rounds,
        "inner_steps": inner_steps,
        "regions": ["us", "eu"],
        "inter_bandwidth_bytes_s": 1.2e7,
        "baseline_loss_curve": [round(x, 5) for x in base_curve],
        "collab_loss_curve": [round(x, 5) for x in collab_curve],
        "final_loss_gap_frac": round(loss_gap, 5),
        "wire_bytes_per_worker_round": int(per_round_wire),
        "fp32_exchange_bytes_per_worker_round": int(per_round_dense),
        "compression_ratio": round(wire / dense, 5),
        "churned_nodes": len(churned),
        "workers_killed": len(doomed),
        "rounds_aborted": aborted,
        "rounds_degraded": sum(w.stats["rounds_degraded"] for w in workers),
        "rebases": sum(w.stats["rebases"] for w in workers),
        "catchup_rounds": sum(w.stats["catchup_rounds"] for w in doomed),
        "digests_identical": len(digests) == 1,
        "overdue_pins": sum(w.overdue_pins() for w in workers),
        "san_trace_digests_identical": d1[0] == d2[0],
        "san_outer_digests_identical": d1[1] == d2[1] and len(d1[1]) == 1,
        "san_overdue_pins": d1[2] + d2[2],
    }
    report.append(f"# Collaborative training: {n_workers} workers x "
                  f"{rounds} rounds x H={inner_steps}, us<->eu at "
                  f"{1.2e7 * 8 / 1e6:.0f} Mbps")
    report.append(f"loss-vs-rounds  baseline: "
                  + " ".join(f"{x:.4f}" for x in base_curve))
    report.append(f"loss-vs-rounds  collab:   "
                  + " ".join(f"{x:.4f}" for x in collab_curve)
                  + f"   (final gap {loss_gap * 100:.2f}%)")
    report.append(f"bytes/round/worker: {per_round_wire / 1e3:.1f} kB "
                  f"compressed vs {per_round_dense / 1e3:.1f} kB fp32 "
                  f"({metrics['compression_ratio']:.4f}x)")
    report.append(f"churn wave: {len(churned)} hosts restarted, "
                  f"{len(doomed)} workers killed mid-round -> "
                  f"aborted={aborted} degraded={metrics['rounds_degraded']} "
                  f"catchup={metrics['catchup_rounds']}")
    report.append(f"outer digests identical across all {len(workers)} "
                  f"workers (incl. rejoined): {metrics['digests_identical']}")
    report.append(f"sanitizer double-run: trace digests equal="
                  f"{metrics['san_trace_digests_identical']} "
                  f"outer digests equal="
                  f"{metrics['san_outer_digests_identical']} "
                  f"overdue pins={metrics['san_overdue_pins']}")
    return metrics


if __name__ == "__main__":
    import sys
    out: List[str] = []
    if "--train-smoke" in sys.argv[1:]:
        metrics = main(out, smoke=True)
        _bench.emit("collab_train_smoke", metrics)
        print("\n".join(out))
        assert metrics["final_loss_gap_frac"] <= 0.05, \
            f"collab loss {metrics['final_loss_gap_frac']:.1%} off baseline"
        assert metrics["compression_ratio"] <= 0.10, \
            f"wire {metrics['compression_ratio']:.3f}x > 0.10x fp32"
        assert metrics["workers_killed"] >= 2, "churn killed < 2 workers"
        assert metrics["rounds_aborted"] == 0, \
            f"{metrics['rounds_aborted']} rounds aborted under churn"
        assert metrics["digests_identical"], "outer state forked"
        assert metrics["catchup_rounds"] >= 2, "rejoiners never caught up"
        assert metrics["overdue_pins"] == 0, "contribution pins leaked"
        assert metrics["san_trace_digests_identical"], \
            "sanitizer double-run trace digests differ"
        assert metrics["san_outer_digests_identical"], \
            "sanitizer double-run outer digests differ"
        assert metrics["san_overdue_pins"] == 0
        print("smoke: OK")
    else:
        metrics = main(out)
        _bench.emit("collab_train", metrics)
        print("\n".join(out))
