"""Fleet-scale scenarios: 1k/10k-node overlays under continuous churn.

    PYTHONPATH=src python benchmarks/fleet_scale.py                # report
    PYTHONPATH=src python benchmarks/fleet_scale.py --fleet-smoke  # CI gates

Each scenario stands up a :func:`repro.core.fleet.make_scale_fleet`
overlay (Trautwein NAT mix, pre-established edges, virtual clock), starts
a continuous churn loop restarting 1% of the NAT'd population every 2
virtual seconds, and then measures the three planes the paper scales:

  * dissemination — registry writes ride the CRDT delta-push plane over
    the scored gossipsub mesh; delivery is the fraction of nodes whose
    ``watch`` callback fired within one push window + 3 gossip rounds,
    and relay fairness is max/mean forwarded-message load;
  * lookup — DHT provide/find_providers pairs between random nodes;
  * anti-entropy — a member registry converges through hub publics via
    MST-summarized sync rounds; probe bytes per exchange are compared
    against the flat per-key summary a v2 round would ship.

The ``--fleet-smoke`` gates (1k nodes, wired into scripts/ci.sh):
  * >=99% mean delivery within 3 gossip rounds under churn;
  * max relay load <= 3x the fleet mean;
  * every DHT lookup finds its provider;
  * sampled nodes pull the full member registry (coverage >= 99%);
  * the whole scenario runs in <= 60 s wall.
"""

from __future__ import annotations

import hashlib
import sys
import time
from typing import Dict, List, Optional

from repro.core.crdt import encode_summary
from repro.core.fleet import ScaleFleet, make_scale_fleet
from repro.core.pubsub import HEARTBEAT


# ------------------------------------------------------------------ phases


def _attach_watchers(fleet: ScaleFleet,
                     arrivals: Dict[str, Dict[str, float]]) -> None:
    """Every node watches ``reg/`` (joining the crdt/reg push topic) and
    records the virtual time its callback first saw each key."""
    sim = fleet.sim
    for node in fleet.nodes:
        def cb(key: str, value: object, origin: str,
               _n: str = node.host.name) -> None:
            arrivals.setdefault(key, {}).setdefault(_n, sim.now)
        node.watch_crdt("reg/", cb)


def _push_phase(fleet: ScaleFleet, arrivals: Dict[str, Dict[str, float]],
                n_writes: int) -> Dict[str, object]:
    """Spaced registry writes on random nodes; each rides the delta-push
    plane as one coalesced doc on crdt/reg.  Delivery counts callbacks
    fired within push-window + 3 gossip rounds of the write."""
    sim, rng = fleet.sim, fleet.sim.rng
    write_t: Dict[str, float] = {}
    for i in range(n_writes):
        w = rng.choice(fleet.nodes)
        key = f"reg/fleet/w{i}"
        w.store.register(key).set((i, w.host.name), sim.now, w.host.name)
        write_t[key] = sim.now
        sim.run(until=sim.now + 1.0)
    window = fleet.nodes[0].crdt_push_window + 3 * HEARTBEAT
    sim.run(until=max(write_t.values()) + window + 0.5)
    n = len(fleet.nodes)
    fracs = []
    for key, t0 in write_t.items():
        got = sum(1 for t in arrivals.get(key, {}).values()
                  if t <= t0 + window)
        fracs.append(got / n)
    return {"writes": n_writes,
            "delivery_mean": round(sum(fracs) / len(fracs), 4),
            "delivery_min": round(min(fracs), 4),
            "window_s": round(window, 2)}


def _relay_stats(fleet: ScaleFleet) -> Dict[str, float]:
    loads = fleet.relay_load()
    mean = sum(loads) / len(loads)
    return {"max": max(loads), "mean": round(mean, 2),
            "ratio": round(max(loads) / mean, 2) if mean else 0.0}


def _run_batch(fleet: ScaleFleet, gens: List[object],
               timeout: float = 8.0) -> List[object]:
    """Drive a batch of generators as concurrent sim processes.  Virtual
    time is the *slowest* member, not the sum — sequential driving would
    drag the whole fleet's heartbeat machinery through minutes of virtual
    time.  Stragglers past ``timeout`` are abandoned (their processes
    finish in the background); failures stay on the returned Process."""
    sim = fleet.sim
    procs = [sim.process(g) for g in gens]
    deadline = sim.now + timeout
    while sim.now < deadline and not all(p.triggered for p in procs):
        sim.run(until=min(deadline, sim.now + 0.25))
    return procs


def _dht_phase(fleet: ScaleFleet, n_lookups: int) -> Dict[str, object]:
    """provide/find_providers pairs between random (mostly NAT'd) nodes
    while the churn loop keeps restarting parts of the overlay."""
    sim, rng = fleet.sim, fleet.sim.rng
    t0 = sim.now
    pairs = [(rng.choice(fleet.nodes), rng.choice(fleet.nodes),
              hashlib.sha256(f"fleet/model/{i}".encode()).digest())
             for i in range(n_lookups)]
    provides = _run_batch(fleet, [p.dht.provide(k) for p, _s, k in pairs],
                          timeout=20.0)
    unprovided = sum(1 for p in provides if not p.triggered or p.failed)
    finds = _run_batch(fleet, [s.dht.find_providers(k)
                               for _p, s, k in pairs], timeout=20.0)
    ok = sum(1 for p in finds
             if p.triggered and not p.failed and p.value)
    failures = sum(1 for p in finds if not p.triggered or p.failed)
    return {"lookups": n_lookups, "ok": ok, "failures": failures,
            "provide_incomplete": unprovided,
            "virtual_s": round(sim.now - t0, 2)}


def _registry_phase(fleet: ScaleFleet, n_members: int, n_hubs: int,
                    n_pulls: int) -> Dict[str, object]:
    """Member-registry anti-entropy: members self-register in ``mreg/``
    (a namespace with no push subscribers, so only sync moves it), upload
    to hub publics, hubs converge star-wise on hub 0 (two concurrent
    rounds: first accumulates the union, second distributes it), and
    sampled NAT'd nodes pull the full registry — all while churn keeps
    restarting members."""
    sim, rng = fleet.sim, fleet.sim.rng
    members = rng.sample(fleet.nodes, min(n_members, len(fleet.nodes)))
    for m in members:
        m.store.register(f"mreg/member/{m.host.name}").set(
            (m.host.region, m.host.name), sim.now, m.host.name)
    member_keys = [f"mreg/member/{m.host.name}" for m in members]
    hubs = rng.sample(fleet.publics, min(n_hubs, len(fleet.publics)))
    before = fleet.summary_bytes()
    failures = 0

    def batch(syncs: List[object]) -> None:
        nonlocal failures
        procs = _run_batch(fleet, syncs)
        failures += sum(1 for p in procs if not p.triggered or p.failed)

    batch([m.sync_crdt_with(rng.choice(hubs).info()) for m in members])
    for _ in range(2):
        batch([h.sync_crdt_with(hubs[0].info()) for h in hubs[1:]])
    pulled = rng.sample(fleet.natted, min(n_pulls, len(fleet.natted)))
    hub_of = {n.host.name: rng.choice(hubs) for n in pulled}

    def coverage_of(node: object) -> float:
        got = sum(1 for k in member_keys
                  if node.store.entry_vv(k) is not None)
        return got / len(member_keys)

    batch([n.sync_crdt_with(hub_of[n.host.name].info()) for n in pulled])
    retry = [n for n in pulled if coverage_of(n) < 0.999]
    if retry:        # e.g. restarted mid-pull: one more round, fresh hub
        batch([n.sync_crdt_with(rng.choice(hubs).info()) for n in retry])
    coverage = [coverage_of(n) for n in pulled]
    after = fleet.summary_bytes()
    probe = after["mst_probe_bytes"] - before["mst_probe_bytes"]
    exchanges = after["mst_exchanges"] - before["mst_exchanges"]
    probe_per_ex = probe / exchanges if exchanges else 0.0
    # what ONE flat v2 summary round against a converged hub would ship
    # latlint: disable=L007 flat-summary byte baseline for the receipt
    flat = len(encode_summary(hubs[0].store.key_digests()))
    return {"members": len(members), "hubs": len(hubs),
            "pulls": len(pulled), "sync_failures": failures,
            "pull_coverage": round(sum(coverage) / len(coverage), 4),
            "mst_probe_bytes": probe, "mst_exchanges": exchanges,
            "probe_bytes_per_exchange": round(probe_per_ex, 1),
            "flat_summary_bytes": flat,
            "probe_vs_flat_ratio": round(probe_per_ex / flat, 4)
            if flat else 0.0}


# ---------------------------------------------------------------- scenario


def run_fleet_scenario(n_nodes: int, seed: int, *, subscribe: bool,
                       n_writes: int, n_lookups: int, n_members: int,
                       n_hubs: int, n_pulls: int,
                       churn_frac: float = 0.01,
                       churn_interval: float = 2.0) -> Dict[str, object]:
    t0 = time.time()
    fleet = make_scale_fleet(n_nodes, seed=seed)
    sim = fleet.sim
    build_wall = time.time() - t0
    arrivals: Dict[str, Dict[str, float]] = {}
    if subscribe:
        _attach_watchers(fleet, arrivals)
        sim.run(until=sim.now + 5.0)            # mesh settles via heartbeats
    sim.process(fleet.churn_loop(churn_frac, churn_interval), daemon=True)
    push: Optional[Dict[str, object]] = None
    relay: Optional[Dict[str, float]] = None
    if subscribe and n_writes:
        push = _push_phase(fleet, arrivals, n_writes)
        relay = _relay_stats(fleet)
    dht = _dht_phase(fleet, n_lookups)
    registry = _registry_phase(fleet, n_members, n_hubs, n_pulls)
    return {"n_nodes": n_nodes, "seed": seed,
            "publics": len(fleet.publics), "natted": len(fleet.natted),
            "edges": fleet.stats["edges"],
            "churn_events": fleet.stats["churn_events"],
            "churn": {"frac": churn_frac, "interval_s": churn_interval},
            "build_wall_s": round(build_wall, 2),
            "push": push, "relay": relay, "dht": dht,
            "registry": registry,
            "virtual_s": round(sim.now, 2),
            "wall_s": round(time.time() - t0, 2)}


def _describe(r: Dict[str, object], report: List[str]) -> None:
    report.append(f"{r['n_nodes']} nodes ({r['publics']} public / "
                  f"{r['natted']} NAT'd), {r['edges']} edges, "
                  f"{r['churn_events']} churn restarts, "
                  f"built {r['build_wall_s']}s, total {r['wall_s']}s wall")
    if r["push"]:
        p, rl = r["push"], r["relay"]
        report.append(f"  push delivery within {p['window_s']}s: "
                      f"mean {p['delivery_mean']:.1%} "
                      f"min {p['delivery_min']:.1%} over {p['writes']} "
                      f"writes; relay load max/mean = {rl['max']}/"
                      f"{rl['mean']} ({rl['ratio']}x)")
    d = r["dht"]
    report.append(f"  dht: {d['ok']}/{d['lookups']} provider lookups ok "
                  f"({d['failures']} failed)")
    g = r["registry"]
    report.append(f"  registry: {g['members']} members via {g['hubs']} "
                  f"hubs, pull coverage {g['pull_coverage']:.1%}, "
                  f"mst probe {g['probe_bytes_per_exchange']:.0f} B/exchange"
                  f" vs flat {g['flat_summary_bytes']} B "
                  f"({g['probe_vs_flat_ratio']:.1%})")


# ------------------------------------------------------------ entry points


_SMOKE_1K = dict(subscribe=True, n_writes=4, n_lookups=16, n_members=60,
                 n_hubs=32, n_pulls=32)
_FULL_1K = dict(subscribe=True, n_writes=6, n_lookups=24, n_members=120,
                n_hubs=48, n_pulls=48)
_FULL_10K = dict(subscribe=False, n_writes=0, n_lookups=12, n_members=200,
                 n_hubs=64, n_pulls=64)


def main_1k(report: List[str], smoke: bool = False) -> Dict[str, object]:
    report.append("# 1k-node fleet under 1%/2s churn (Trautwein NAT mix)")
    r = run_fleet_scenario(1000, seed=3,
                           **(_SMOKE_1K if smoke else _FULL_1K))
    _describe(r, report)
    return r


def main_10k(report: List[str], smoke: bool = False) -> Dict[str, object]:
    report.append("# 10k-node fleet under 1%/2s churn (no subscribe-all: "
                  "DHT + registry anti-entropy planes)")
    r = run_fleet_scenario(2000 if smoke else 10_000, seed=5, **_FULL_10K)
    _describe(r, report)
    return r


def fleet_smoke() -> int:
    """CI gates over the 1k scenario."""
    r = run_fleet_scenario(1000, seed=3, **_SMOKE_1K)
    out: List[str] = []
    _describe(r, out)
    for line in out:
        print(f"[fleet] {line.strip()}")
    checks = [
        ("delivery >= 99% within 3 gossip rounds",
         r["push"]["delivery_mean"] >= 0.99),
        ("relay load max <= 3x mean", r["relay"]["ratio"] <= 3.0),
        ("all dht lookups find their provider",
         r["dht"]["ok"] == r["dht"]["lookups"]),
        ("registry pull coverage >= 99%",
         r["registry"]["pull_coverage"] >= 0.99),
        ("scenario wall time <= 60s", r["wall_s"] <= 60.0),
    ]
    failed = [name for name, ok in checks if not ok]
    for name in failed:
        print(f"[fleet] FAIL: {name}")
    if failed:
        return 1
    print(f"[fleet] all {len(checks)} gates passed")
    return 0


if __name__ == "__main__":
    if "--fleet-smoke" in sys.argv:
        raise SystemExit(fleet_smoke())
    out: List[str] = []
    main_1k(out)
    main_10k(out)
    print("\n".join(out))
