"""CRDT anti-entropy convergence: how long until every replica agrees."""

from __future__ import annotations

from typing import Generator, List

from repro.core.fleet import make_fleet


def run_convergence(n_peers: int, interval: float = 2.0) -> dict:
    fleet = make_fleet(n_peers, seed=55, same_region="us")
    sim = fleet.sim
    # every peer makes a local write
    for i, node in enumerate(fleet.peers):
        node.store.counter("steps").increment(node.host.name, i + 1)
        node.store.orset("versions").add(i, node.host.name)
    target = sum(range(1, n_peers + 1))
    loops = [sim.process(n.anti_entropy_loop(interval)) for n in fleet.peers]
    t0 = sim.now
    deadline = t0 + 3600
    rounds = 0
    while sim.now < deadline:
        sim.run(until=sim.now + interval)
        rounds += 1
        if all(n.store.counter("steps").value() == target
               for n in fleet.peers):
            break
    digests = {n.store.digest() for n in fleet.peers}
    return {"n": n_peers, "t_converge": sim.now - t0,
            "converged": len(digests) == 1
            and fleet.peers[0].store.counter("steps").value() == target}


def main(report: List[str]) -> None:
    report.append("# CRDT store convergence (random pairwise anti-entropy, "
                  "2 s interval)")
    report.append(f"{'peers':>6} {'t_converge_s':>12} {'converged':>9}")
    for n in (4, 8, 16):
        r = run_convergence(n)
        report.append(f"{r['n']:>6} {r['t_converge']:>12.1f} "
                      f"{str(r['converged']):>9}")


if __name__ == "__main__":
    out: List[str] = []
    main(out)
    print("\n".join(out))
