"""CRDT replication-plane benchmarks: delta-sync efficiency, push-plane
convergence latency, v1/v2 interop, and anti-entropy convergence time.

    PYTHONPATH=src python benchmarks/crdt_sync.py               # full report
    PYTHONPATH=src python benchmarks/crdt_sync.py --sync-smoke  # CI gates
    PYTHONPATH=src python benchmarks/crdt_sync.py --mst-smoke   # MST gate

The ``--mst-smoke`` gate: at 10k registry keys with 1% churn on both
sides, the Merkle-summary walk localizes the divergence in <=10% of the
bytes the flat per-key v2 summary would move.

The ``--sync-smoke`` gates (wired into scripts/ci.sh):
  * at ~1k registry-shaped keys with 1% churn per round, the v2 protocol
    (digest probe → per-key digest summary → per-key delta transfer) moves
    ≤10% of the bytes the v1 full-state exchange moves;
  * with the delta push plane enabled, a write reaches every connected
    subscriber's ``watch`` callback within one gossip round — no
    anti-entropy tick is running at all;
  * a mixed v1↔v2 pair still converges in both directions (the v2 node
    falls back to the full-state exchange after one NOT_FOUND).
"""

from __future__ import annotations

import sys
from typing import Dict, Generator, List

from repro.core import LatticaNode, Network, Sim
from repro.core.fleet import make_fleet, wait_converged

N_KEYS = 1000
VERSIONS_PER_KEY = 8
CHURN = 0.01


# ------------------------------------------------------------------ helpers


def _digest(step: int, key_idx: int) -> bytes:
    return bytes([(step * 31 + key_idx * 7 + i) % 256 for i in range(32)])


def _seed_registry(node: LatticaNode, n_keys: int, versions: int) -> None:
    """Registry-shaped state: one ORSet of (step, codec, digest) version
    tuples per key — the same shape the checkpoint registry uses."""
    name = node.host.name
    for i in range(n_keys):
        s = node.store.orset(f"reg/k{i:04d}")
        for v in range(versions):
            s.add((v + 1, 0x70, _digest(v + 1, i)), name)


def _churn(node: LatticaNode, n_keys: int, frac: float, round_no: int) -> int:
    """Mutate ``frac`` of the keys (one new version tuple each)."""
    name = node.host.name
    step = VERSIONS_PER_KEY + round_no
    n = max(1, int(n_keys * frac))
    for i in range(0, n_keys, n_keys // n):
        node.store.orset(f"reg/k{i:04d}").add(
            (step, 0x70, _digest(step, i)), name)
    return n


def _pair(proto: str, seed: int = 1) -> tuple:
    """Two directly-dialable public nodes speaking ``proto`` (push off so
    measured bytes are purely the sync protocol's)."""
    sim = Sim(seed=seed)
    net = Network(sim)
    a = LatticaNode(net, "a", crdt_proto=proto, crdt_push=False)
    b = LatticaNode(net, "b", region="eu", crdt_proto=proto, crdt_push=False)
    sim.run_process(a.connect_info(b.info()))
    return sim, a, b


def _sync_bytes(sim: Sim, a: LatticaNode, b: LatticaNode) -> int:
    """One anti-entropy round a→b; returns the bytes it moved (both
    directions of payload, as counted by the node's crdt_stats)."""
    before = a.crdt_stats["tx_bytes"] + a.crdt_stats["rx_bytes"]
    sim.run_process(a.sync_crdt_with(b.info()), until=sim.now + 600)
    return a.crdt_stats["tx_bytes"] + a.crdt_stats["rx_bytes"] - before


# ------------------------------------------------ 1. delta-sync efficiency


def run_delta_efficiency(n_keys: int = N_KEYS, churn: float = CHURN,
                         rounds: int = 3) -> Dict[str, float]:
    """Steady-state bytes per round at ``churn`` fraction of keys mutated:
    v2 per-key deltas vs the v1 full-store swap, identical state both
    times."""
    results: Dict[str, List[int]] = {"v1": [], "v2": []}
    for proto in ("v2", "v1"):
        sim, a, b = _pair(proto)
        _seed_registry(a, n_keys, VERSIONS_PER_KEY)
        _sync_bytes(sim, a, b)                   # initial replication
        assert a.store.digest() == b.store.digest()
        for r in range(rounds):
            _churn(a, n_keys, churn, r + 1)
            moved = _sync_bytes(sim, a, b)
            assert a.store.digest() == b.store.digest(), "round diverged"
            results[proto].append(moved)
    v1 = sum(results["v1"]) / len(results["v1"])
    v2 = sum(results["v2"]) / len(results["v2"])
    return {"n_keys": n_keys, "churn": churn, "rounds": rounds,
            "v1_bytes_per_round": v1, "v2_bytes_per_round": v2,
            "ratio": v2 / v1 if v1 else 1.0}


# ------------------------------------------- 1b. MST summary localization


MST_N_KEYS = 10_000


def run_mst_efficiency(n_keys: int = MST_N_KEYS, churn: float = CHURN,
                       rounds: int = 3,
                       versions: int = 4) -> Dict[str, float]:
    """Merkle-walk localization bytes vs the flat v2 summary at registry
    scale.  Two identical ``n_keys``-key stores diverge by ``churn`` on
    *both* sides each round; the mst pair pays a log-depth probe walk to
    localize the differing keys, the v2 pair re-ships the full per-key
    digest summary.  Sync caches are cleared between rounds: at fleet
    scale a node rarely re-syncs the partner it converged with last, so
    the cache-miss path is the one that matters."""
    probe: List[int] = []
    flat: List[int] = []
    for proto, counter, out in (("mst", "mst_probe_bytes", probe),
                                ("v2", "summary_bytes", flat)):
        sim, a, b = _pair(proto, seed=2)
        _seed_registry(a, n_keys, versions)
        _sync_bytes(sim, a, b)              # initial replication
        assert a.store.digest() == b.store.digest()
        for r in range(rounds):
            _churn(a, n_keys, churn, 2 * r + 1)
            _churn(b, n_keys, churn, 2 * r + 2)
            a._crdt_sync_cache.clear()
            b._crdt_sync_cache.clear()
            before = a.crdt_stats[counter]
            _sync_bytes(sim, a, b)
            assert a.store.digest() == b.store.digest(), "round diverged"
            out.append(a.crdt_stats[counter] - before)
    probe_mean = sum(probe) / len(probe)
    flat_mean = sum(flat) / len(flat)
    return {"n_keys": n_keys, "churn": churn, "rounds": rounds,
            "versions": versions,
            "mst_probe_bytes_per_round": probe_mean,
            "flat_summary_bytes_per_round": flat_mean,
            "ratio": probe_mean / flat_mean if flat_mean else 1.0}


# ------------------------------------------------ 2. push-plane latency


def run_push_latency(n_peers: int = 8, seed: int = 44) -> Dict[str, float]:
    """A write on one peer must reach every other connected peer's
    ``watch`` callback via the crdt/<ns> delta push — with *no*
    anti-entropy loop running anywhere."""
    fleet = make_fleet(n_peers, seed=seed, same_region="us")
    sim = fleet.sim
    writer = fleet.peers[0]
    subs = fleet.peers[1:]
    fired: Dict[str, float] = {}

    def cb_for(name: str):
        def cb(key: str, value: object, origin: str) -> None:
            if origin == "remote" and name not in fired:
                fired[name] = sim.now
        return cb

    for n in subs:
        n.watch_crdt("bench/", cb_for(n.host.name))
    sim.run(until=sim.now + 5)          # subscription propagation settles
    t0 = sim.now
    writer.store.orset("bench/versions").add((1, b"\x01" * 32),
                                             writer.host.name)
    sim.run(until=sim.now + 10)
    latencies = [t - t0 for t in fired.values()]
    return {"n_subscribers": len(subs), "reached": len(fired),
            "max_latency_s": max(latencies) if latencies else float("inf"),
            "push_docs": writer.crdt_stats["push_published"],
            "push_bytes": writer.crdt_stats["push_bytes"]}


# ------------------------------------------------ 3. v1 <-> v2 interop


def run_mixed_interop(seed: int = 9) -> Dict[str, bool]:
    """A v1-only peer and a v2 peer must converge in both directions."""
    sim = Sim(seed=seed)
    net = Network(sim)
    v2 = LatticaNode(net, "v2node", crdt_proto="v2", crdt_push=False)
    v1 = LatticaNode(net, "v1node", region="eu", crdt_proto="v1")
    sim.run_process(v2.connect_info(v1.info()))

    v2.store.counter("steps/f").increment("v2node", 3)
    v2.store.orset("reg/k").add((1, b"\x01" * 32), "v2node")
    v1.store.counter("steps/f").increment("v1node", 4)
    sim.run_process(v2.sync_crdt_with(v1.info()), until=sim.now + 600)
    v2_initiated = v2.store.digest() == v1.store.digest()

    v1.store.orset("reg/k").add((2, b"\x02" * 32), "v1node")
    sim.run_process(v1.sync_crdt_with(v2.info()), until=sim.now + 600)
    v1_initiated = v1.store.digest() == v2.store.digest()
    return {"v2_initiated_converged": v2_initiated,
            "v1_initiated_converged": v1_initiated,
            "fallbacks": v2.crdt_stats["full_exchanges"],
            "value_agree": (v2.store.counter("steps/f").value()
                            == v1.store.counter("steps/f").value() == 7)}


# ------------------------------------------------ 4. anti-entropy fallback


def run_convergence(n_peers: int, interval: float = 2.0,
                    push: bool = True) -> dict:
    """Whole-fleet convergence time after every peer writes.  With the push
    plane on, writes go out event-driven and anti-entropy only mops up;
    with it off, this is the old luck-driven random-pairwise baseline.
    ``wait_converged`` (watch-based) replaces the old sleep-step-poll."""
    fleet = make_fleet(n_peers, seed=55, same_region="us")
    sim = fleet.sim
    for node in fleet.peers:
        node.crdt_push = node.crdt_push and push
        if push:
            node.join_crdt_push("steps")
            node.join_crdt_push("versions")
    sim.run(until=sim.now + 5)          # subscription propagation settles
    for i, node in enumerate(fleet.peers):
        node.store.counter("steps").increment(node.host.name, i + 1)
        node.store.orset("versions").add(i, node.host.name)
    target = sum(range(1, n_peers + 1))
    for n in fleet.peers:
        sim.process(n.anti_entropy_loop(interval))
    t0 = sim.now
    converged = wait_converged(sim, fleet.peers, timeout=3600)
    return {"n": n_peers, "push": push, "t_converge": sim.now - t0,
            "converged": converged
            and fleet.peers[0].store.counter("steps").value() == target}


# ---------------------------------------------------------------- reports


def main(report: List[str]) -> Dict[str, object]:
    report.append("# CRDT store convergence (anti-entropy 2 s interval, "
                  "with/without delta push)")
    report.append(f"{'peers':>6} {'push':>5} {'t_converge_s':>12} "
                  f"{'converged':>9}")
    rows = []
    for n in (4, 8, 16):
        for push in (False, True):
            r = run_convergence(n, push=push)
            rows.append(r)
            report.append(f"{r['n']:>6} {str(r['push']):>5} "
                          f"{r['t_converge']:>12.2f} "
                          f"{str(r['converged']):>9}")
    return {"convergence": rows}


def main_sync(report: List[str]) -> Dict[str, object]:
    report.append("# v2 delta sync vs v1 full-state exchange "
                  f"({N_KEYS} keys, {CHURN:.0%} churn/round)")
    eff = run_delta_efficiency()
    report.append(f"v1 full-state: {eff['v1_bytes_per_round']:>10.0f} B/round")
    report.append(f"v2 delta:      {eff['v2_bytes_per_round']:>10.0f} B/round"
                  f"  ({eff['ratio']:.1%} of v1)")
    push = run_push_latency()
    report.append(f"# delta push: write -> {push['reached']}/"
                  f"{push['n_subscribers']} subscriber watch callbacks, "
                  f"max latency {push['max_latency_s']:.2f}s "
                  f"({push['push_bytes']} B published, no anti-entropy)")
    mixed = run_mixed_interop()
    report.append(f"# mixed pair: v2-initiated converged = "
                  f"{mixed['v2_initiated_converged']}, v1-initiated = "
                  f"{mixed['v1_initiated_converged']} "
                  f"(v1 fallbacks used: {mixed['fallbacks']})")
    return {"delta_efficiency": eff, "push_latency": push,
            "mixed_interop": mixed}


def main_mst(report: List[str]) -> Dict[str, object]:
    report.append(f"# MST probe walk vs flat v2 summary ({MST_N_KEYS} keys, "
                  f"{CHURN:.0%} churn/round, both sides diverging)")
    eff = run_mst_efficiency()
    report.append(f"flat v2 summary: "
                  f"{eff['flat_summary_bytes_per_round']:>10.0f} B/round")
    report.append(f"mst probe walk:  "
                  f"{eff['mst_probe_bytes_per_round']:>10.0f} B/round"
                  f"  ({eff['ratio']:.1%} of flat)")
    return {"mst_efficiency": eff}


def mst_smoke() -> int:
    """CI gate: at registry scale (10k keys, 1% churn) the Merkle walk
    must localize divergence in <=10% of the flat summary's bytes."""
    eff = run_mst_efficiency()
    print(f"[crdt-sync] mst probe {eff['mst_probe_bytes_per_round']:.0f} "
          f"B/round vs flat summary "
          f"{eff['flat_summary_bytes_per_round']:.0f} B/round "
          f"({eff['ratio']:.1%}) at {eff['n_keys']} keys / "
          f"{eff['churn']:.0%} churn")
    if eff["ratio"] > 0.10:
        print(f"[crdt-sync] FAIL: mst probe moved {eff['ratio']:.1%} of "
              "flat summary bytes (gate: <=10%)")
        return 1
    print("[crdt-sync] mst gate passed")
    return 0


def sync_smoke() -> int:
    """CI gates for the delta replication plane."""
    failures = []
    eff = run_delta_efficiency()
    print(f"[crdt-sync] v2 moves {eff['v2_bytes_per_round']:.0f} B/round vs "
          f"v1 {eff['v1_bytes_per_round']:.0f} B/round "
          f"({eff['ratio']:.1%}) at {eff['n_keys']} keys / "
          f"{eff['churn']:.0%} churn")
    if eff["ratio"] > 0.10:
        failures.append(
            f"delta sync moved {eff['ratio']:.1%} of full-state bytes "
            "(gate: <=10%)")

    push = run_push_latency()
    print(f"[crdt-sync] push reached {push['reached']}/"
          f"{push['n_subscribers']} subscribers, max latency "
          f"{push['max_latency_s']:.2f}s (no anti-entropy running)")
    if push["reached"] < push["n_subscribers"]:
        failures.append(
            f"push reached only {push['reached']}/{push['n_subscribers']} "
            "subscribers")
    elif push["max_latency_s"] > 3.0:
        failures.append(
            f"push latency {push['max_latency_s']:.2f}s exceeds one gossip "
            "round (gate: <=3s)")

    mixed = run_mixed_interop()
    print(f"[crdt-sync] mixed v1<->v2 pair converged both directions: "
          f"{mixed['v2_initiated_converged'] and mixed['v1_initiated_converged']}")
    if not (mixed["v2_initiated_converged"] and mixed["v1_initiated_converged"]
            and mixed["value_agree"]):
        failures.append("mixed v1<->v2 pair failed to converge")

    if failures:
        for f in failures:
            print(f"[crdt-sync] FAIL: {f}")
        return 1
    print("[crdt-sync] all gates passed")
    return 0


if __name__ == "__main__":
    if "--sync-smoke" in sys.argv:
        raise SystemExit(sync_smoke())
    if "--mst-smoke" in sys.argv:
        raise SystemExit(mst_smoke())
    out: List[str] = []
    main_sync(out)
    main_mst(out)
    main(out)
    print("\n".join(out))
